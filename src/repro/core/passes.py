"""Pass manager over the physical-plan IR (paper §4 rewrites, re-expressed).

Each optimization is a ``Pass``: a pure ``PhysicalPlan -> PhysicalPlan``
transform.  ``PassPipeline`` runs a configured sequence, re-validating and
re-typechecking the plan after every pass (so a broken transform fails at
compile time, not in an executor thread) and recording a per-pass trace
(op counts, wall time, notes) for the planner and for debugging.

Passes:

* ``FuseChainsPass``    — operator fusion: collapse single-consumer linear
  chains into one ``Fuse`` op.  Optimization hints of the constituents
  (``high_variance``, ``replicas``) survive onto the fused op, so fusion
  composes with competitive execution instead of silently disabling it.
* ``CompetitivePass``   — replicate high-variance ops k times, consume with
  a wait-for-any op.
* ``FuseLookupsPass``   — locality: fuse lookups into their consumer and
  annotate the result for resolved-ref dynamic dispatch.
* ``PlaceKernelsPass``  — kernel placement: swap map steps tagged (or
  pattern-matched) as registered attention/scan computations for their
  jitted Pallas twins, so lowered chains dispatch custom kernels natively.
* ``LowerJaxChainsPass`` — lower eligible fused JAX map chains into single
  ``jax.jit`` callables (XLA-level fusion on top of graph-level fusion).

``build_pipeline`` maps the planner's optimization flags onto a pass
configuration — the plan *is* the pass configuration.
"""
from __future__ import annotations

import copy
import dataclasses
import time
from typing import Any, Dict, List, Optional, Protocol, Tuple, \
    runtime_checkable

from repro.core import operators as ops
from repro.core.ir import SOURCE_ID, PhysicalOp, PhysicalPlan
from repro.core.lowering import (DEFAULT_BUCKETS, fuse_is_jax_lowerable,
                                 lower_fuse, op_is_jax_lowerable)


@dataclasses.dataclass
class PassTrace:
    name: str
    ops_before: int
    ops_after: int
    duration_s: float
    notes: List[str] = dataclasses.field(default_factory=list)

    def __repr__(self):
        extra = f" ({'; '.join(self.notes)})" if self.notes else ""
        return (f"{self.name}: {self.ops_before} -> {self.ops_after} ops "
                f"in {self.duration_s * 1e3:.2f}ms{extra}")


class PassContext:
    """Mutable per-compilation state shared by the passes in a pipeline."""

    def __init__(self):
        self.trace: List[PassTrace] = []
        self.notes: List[str] = []

    def note(self, msg: str):
        self.notes.append(msg)


@runtime_checkable
class Pass(Protocol):
    """A plan transform.  Implementations must be pure w.r.t. the input
    plan (``PhysicalPlan`` is immutable; build a new one via ``with_ops``)."""
    name: str

    def run(self, plan: PhysicalPlan, ctx: PassContext) -> PhysicalPlan:
        ...


class PassPipeline:
    """Runs passes in order with post-pass validation + typechecking.

    ``verify=True`` turns the pass suite into a differentially checked
    compiler: the static verifier's structural checks run between every
    pass, and a pass that introduces new error diagnostics (CF501) or
    changes the inferred per-edge types of surviving ops (CF502) fails
    the compile with a :class:`repro.analysis.VerificationError` naming
    the offending pass — instead of shipping a silently miscompiled plan
    to the runtime."""

    def __init__(self, passes: List[Pass], *, validate: bool = True,
                 verify: bool = False):
        self.passes = list(passes)
        self.validate = validate
        self.verify = verify

    def run(self, plan: PhysicalPlan,
            ctx: Optional[PassContext] = None) -> PhysicalPlan:
        ctx = ctx or PassContext()
        if self.validate:
            plan.validate()
            plan.typecheck()
        snapshot = None
        if self.verify:
            from repro.analysis import pass_snapshot
            snapshot = pass_snapshot(plan)
        for p in self.passes:
            before = len(plan.ops)
            notes_start = len(ctx.notes)
            t0 = time.perf_counter()
            plan = p.run(plan, ctx)
            dt = time.perf_counter() - t0
            if self.validate:
                plan.validate()
                plan.typecheck()   # every pass must preserve well-typedness
            if snapshot is not None:
                from repro.analysis import verify_pass_step
                snapshot = verify_pass_step(p.name, plan, snapshot)
            ctx.trace.append(PassTrace(p.name, before, len(plan.ops), dt,
                                       list(ctx.notes[notes_start:])))
        return plan

    def __repr__(self):
        return "PassPipeline[" + " -> ".join(p.name for p in self.passes) + "]"


# ---------------------------------------------------------------------------
# helpers shared by the fusion-shaped passes
# ---------------------------------------------------------------------------

def _sub_ops(op: ops.Operator) -> List[ops.Operator]:
    return list(op.ops) if isinstance(op, ops.Fuse) else [op]


def _starts_with_lookup(op: ops.Operator) -> bool:
    subs = _sub_ops(op)
    return bool(subs) and isinstance(subs[0], ops.Lookup)


def _ends_with_lookup(op: ops.Operator) -> bool:
    subs = _sub_ops(op)
    return bool(subs) and isinstance(subs[-1], ops.Lookup)


def _merge(plan: PhysicalPlan, up: PhysicalOp, down: PhysicalOp) -> PhysicalPlan:
    """Replace ``up -> down`` with one fused op in ``down``'s slot.  Hints
    from BOTH constituents survive (fusion must not disable competitive
    replication downstream — see ISSUE satellite on dropped hints)."""
    fused = ops.Fuse(_sub_ops(up.op) + _sub_ops(down.op))
    fused.resource_class = down.placement
    fused.batching = down.batching
    fused.high_variance = up.high_variance or down.high_variance
    fused.competitive_replicas = max(up.replicas, down.replicas)
    merged = down.replace(
        op=fused, inputs=up.inputs,
        placement=down.placement, batching=down.batching,
        high_variance=fused.high_variance,
        replicas=fused.competitive_replicas,
        locality_ref_column=down.locality_ref_column or up.locality_ref_column,
        locality_const=down.locality_const or up.locality_const)
    new_ops = [merged if o.op_id == down.op_id else o
               for o in plan.ops if o.op_id != up.op_id]
    return plan.with_ops(new_ops)


def _fusible_edge(plan: PhysicalPlan, down: PhysicalOp,
                  counts: Dict[int, int]) -> Optional[PhysicalOp]:
    """The structural preconditions shared by fusion and lookup-fusion:
    ``down`` has one input, which is a non-source op with exactly one
    consumer, itself single-input, not the output, not wait-any."""
    if len(down.inputs) != 1 or down.inputs[0] == SOURCE_ID:
        return None
    up = plan.op(down.inputs[0])
    if counts.get(up.op_id, 0) != 1 or up.op_id == plan.output_id:
        return None
    if len(up.inputs) != 1 or up.wait_any:
        return None
    return up


@dataclasses.dataclass
class FuseChainsPass:
    """Operator fusion (paper §4): greedily collapse linear chains."""
    across_resource_classes: bool = False
    preserve_lookup_boundaries: bool = False
    name: str = dataclasses.field(default="fuse-chains", init=False)

    def run(self, plan: PhysicalPlan, ctx: PassContext) -> PhysicalPlan:
        fused_edges = 0
        changed = True
        while changed:
            changed = False
            counts = plan.consumer_counts()
            for down in plan.ops:
                up = _fusible_edge(plan, down, counts)
                if up is None:
                    continue
                if self.preserve_lookup_boundaries and \
                        _starts_with_lookup(down.op):
                    # keep the upstream un-fused so dynamic dispatch sees
                    # the resolved ref (the paper's to-be-continued split)
                    continue
                if not self.across_resource_classes and \
                        up.placement != down.placement:
                    continue
                if up.batching != down.batching:
                    continue
                plan = _merge(plan, up, down)
                fused_edges += 1
                changed = True
                break
        if fused_edges:
            ctx.note(f"fused {fused_edges} edges")
        return plan


@dataclasses.dataclass
class CompetitivePass:
    """Competitive execution (paper §4): replicate high-variance ops and
    consume the replicas with wait-for-any."""
    default_replicas: int = 3
    name: str = dataclasses.field(default="competitive", init=False)

    def run(self, plan: PhysicalPlan, ctx: PassContext) -> PhysicalPlan:
        next_id = plan.next_id()
        new_ops: List[PhysicalOp] = []
        expanded = 0
        for o in plan.ops:
            k = o.replicas or (self.default_replicas if o.high_variance
                               else 0)
            if k <= 1 or o.wait_any:
                new_ops.append(o)
                continue
            replica_ids = []
            for _ in range(k):
                rep_op = copy.copy(o.op)
                rep_op.competitive_replicas = 0
                rep_op.high_variance = False
                new_ops.append(PhysicalOp(
                    op_id=next_id, op=rep_op, inputs=o.inputs,
                    placement=o.placement, batching=o.batching,
                    locality_ref_column=o.locality_ref_column,
                    locality_const=o.locality_const))
                replica_ids.append(next_id)
                next_id += 1
            # the original slot becomes the wait-for-any consumer, so every
            # downstream reference to o.op_id keeps working; the anyof is a
            # trivial pass-through — always place it on cpu, never on the
            # scarce accelerator pool
            new_ops.append(PhysicalOp(
                op_id=o.op_id, op=ops.AnyOf(), inputs=tuple(replica_ids),
                placement="cpu", wait_any=True))
            expanded += 1
            ctx.note(f"%{o.op_id} ({o.op.name}) x{k}")
        if expanded:
            ctx.note(f"replicated {expanded} ops")
        return plan.with_ops(new_ops)


@dataclasses.dataclass
class FuseLookupsPass:
    """Data locality (paper §4): fuse each lookup into its single consumer
    so compute is colocated with the cached data, then annotate every op
    containing a lookup for resolved-ref dynamic dispatch."""
    name: str = dataclasses.field(default="fuse-lookups", init=False)

    def run(self, plan: PhysicalPlan, ctx: PassContext) -> PhysicalPlan:
        changed = True
        while changed:
            changed = False
            counts = plan.consumer_counts()
            for down in plan.ops:
                up = _fusible_edge(plan, down, counts)
                if up is None or not _ends_with_lookup(up.op):
                    continue
                plan = _merge(plan, up, down)
                changed = True
                break
        # annotate for dynamic dispatch: the scheduler defers placement
        # until the ref is resolved, then prefers an executor caching it
        new_ops = []
        annotated = 0
        for o in plan.ops:
            lk = next((s for s in _sub_ops(o.op)
                       if isinstance(s, ops.Lookup)), None)
            if lk is not None and o.locality_key is None:
                o = o.replace(
                    locality_ref_column=lk.key if lk.is_column else None,
                    locality_const=None if lk.is_column else lk.key)
                annotated += 1
            new_ops.append(o)
        if annotated:
            ctx.note(f"annotated {annotated} lookup ops for locality")
        return plan.with_ops(new_ops)


@dataclasses.dataclass
class LowerJaxChainsPass:
    """Lower fused GPU-placed JAX map/filter chains to single ``jax.jit``
    callables — XLA fuses across operator boundaries, one dispatch/row.
    ``Filter`` members lower as boolean masking inside the jitted body
    (rows compact only at the device->host boundary), so filter-containing
    chains fuse instead of breaking the chain.

    With ``batched=True`` (default) the chain is lowered to a
    ``BatchedJittedFuse``: whole row batches execute as ONE vmapped XLA
    dispatch, with row counts padded to ``bucket_sizes`` so recompiles are
    bounded.  The op is annotated ``batchable`` + ``device_resident`` with
    the chosen buckets, so the runtime feeds merged request tables straight
    into the batched callable and keeps batches device-resident across
    adjacent lowered nodes.

    With ``min_ops <= 1`` bare (un-fused) GPU maps/filters lower too —
    that is what turns a multi-node accelerator chain the fusion pass left
    split (different batching hints, fan-out boundaries) into a
    device-resident pipeline."""
    min_ops: int = 2
    batched: bool = True
    bucket_sizes: tuple = DEFAULT_BUCKETS
    # per-op overrides (SLO optimizer's PlanConfig): op_id -> padding
    # buckets / batched-vs-per-row decision, so bucket sizes and lowering
    # mode stop being global constants
    bucket_overrides: Dict[int, Tuple[int, ...]] = \
        dataclasses.field(default_factory=dict)
    batched_overrides: Dict[int, bool] = \
        dataclasses.field(default_factory=dict)
    name: str = dataclasses.field(default="lower-jax-chains", init=False)

    def run(self, plan: PhysicalPlan, ctx: PassContext) -> PhysicalPlan:
        new_ops = []
        lowered = 0
        for o in plan.ops:
            target = None
            if fuse_is_jax_lowerable(o.op, o.placement, self.min_ops):
                target = o.op
            elif (self.min_ops <= 1 and o.placement == "gpu"
                    and not isinstance(o.op, ops.Fuse)
                    and op_is_jax_lowerable(o.op)):
                target = ops.Fuse([o.op])
                target.resource_class = o.placement
                target.batching = o.batching
                target.high_variance = o.high_variance
                target.competitive_replicas = o.replicas
            if target is not None:
                batched = self.batched_overrides.get(o.op_id, self.batched)
                buckets = tuple(self.bucket_overrides.get(
                    o.op_id, self.bucket_sizes))
                lo = lower_fuse(target, batched=batched,
                                bucket_sizes=buckets)
                o = o.replace(op=lo, batchable=batched,
                              batch_buckets=buckets if batched else (),
                              device_resident=batched)
                lowered += 1
                kind = "vmap-batched" if batched else "per-row"
                ctx.note(f"%{o.op_id}: {len(o.op.ops)} ops -> 1 jitted fn "
                         f"({kind})")
            new_ops.append(o)
        if lowered:
            ctx.note(f"lowered {lowered} chains to XLA")
        return plan.with_ops(new_ops)


@dataclasses.dataclass
class PlaceKernelsPass:
    """Kernel placement (PRETZEL-style white-box step): swap map steps that
    compute a registered attention/scan (tagged by ``kernels.ops.kernel_step``
    or pattern-matched via ``kernels.ops.register_pattern``) for their jitted
    Pallas twins.

    The twin has the same ``jax.Array`` signature as the reference step, so
    the rewritten map stays lowerable and slots into the ``compose_steps``
    body of ``JittedFuse``/``BatchedJittedFuse`` like any other step — a
    lowered chain already owns its batch on device, so the kernel consumes
    the ``DeviceTable`` columns with no extra host<->device copies.  Under
    the chain's ``jax.vmap`` a ``custom_vmap`` rule maps the row axis onto
    the kernel's native batch dimension: ONE Pallas dispatch per batch.

    Twins are memoized per ``(kernel, params)``, so ``chain_signature`` —
    and with it the ``ExecutableCache`` key and per-chain routing state —
    keys on kernel identity + block-size params: recompiles of the same
    flow share executables/profiles, while chains differing only in tile
    params stay separate variants.

    Runs BEFORE fusion/lowering so the placed steps flow through them the
    normal way.  Only ``gpu``-placed ops are rewritten: those are the ones
    the lowering pass turns into device-resident chains."""
    name: str = dataclasses.field(default="place-kernels", init=False)

    def run(self, plan: PhysicalPlan, ctx: PassContext) -> PhysicalPlan:
        from repro.kernels import ops as kops

        new_ops, placed_total = [], 0
        for o in plan.ops:
            if o.placement != "gpu":
                new_ops.append(o)
                continue
            subs = _sub_ops(o.op)
            placed_here: List[str] = []
            new_subs = []
            for s in subs:
                twin = None
                if isinstance(s, ops.Map) and not isinstance(s, ops.Filter):
                    twin = kops.placed_twin(s.fn)
                if twin is None:
                    new_subs.append(s)
                    continue
                rep = copy.copy(s)
                rep.fn = twin
                rep.__post_init__()     # re-derive _arg_types/_schema
                new_subs.append(rep)
                placed_here.append(repr(kops.match_kernel(s.fn)))
            if not placed_here:
                new_ops.append(o)
                continue
            if isinstance(o.op, ops.Fuse):
                new_op = ops.Fuse(new_subs)
                new_op.resource_class = o.op.resource_class
                new_op.batching = o.op.batching
                new_op.high_variance = o.op.high_variance
                new_op.competitive_replicas = o.op.competitive_replicas
            else:
                new_op = new_subs[0]
            new_ops.append(o.replace(op=new_op,
                                     kernels=tuple(placed_here)))
            placed_total += len(placed_here)
            ctx.note(f"%{o.op_id}: placed {', '.join(placed_here)}")
        if placed_total:
            ctx.note(f"placed {placed_total} Pallas kernels")
        return plan.with_ops(new_ops)


@dataclasses.dataclass
class ApplyPlanConfigPass:
    """Stamp an SLO optimizer ``PlanConfig``'s compile-time per-node
    choices onto the IR: placement overrides and competitive replication
    factors.  Runs early (before competitive/fusion), so the stamped
    annotations flow through the later passes the normal way; config keys
    are compiled-plan op ids, which are stable across recompiles of the
    same flow because fusion keeps the downstream op's id."""
    config: Any            # duck-typed repro.profiling.optimizer.PlanConfig
    name: str = dataclasses.field(default="apply-config", init=False)

    def run(self, plan: PhysicalPlan, ctx: PassContext) -> PhysicalPlan:
        placements = self.config.placement_overrides()
        replicas = self.config.replica_overrides()
        new_ops, stamped = [], 0
        for o in plan.ops:
            kw = {}
            pl = placements.get(o.op_id)
            if pl is not None and pl != o.placement:
                kw["placement"] = pl
            k = replicas.get(o.op_id)
            if k is not None and k != o.replicas:
                kw["replicas"] = k
                kw["high_variance"] = True
            if kw:
                o = o.replace(**kw)
                stamped += 1
            new_ops.append(o)
        if stamped:
            ctx.note(f"stamped config onto {stamped} ops")
        return plan.with_ops(new_ops)


def build_pipeline(*, fusion: bool = False, competitive_exec: bool = False,
                   locality: bool = False, jit_fusion: bool = True,
                   batched_lowering: bool = True,
                   default_replicas: int = 3,
                   plan_config=None,
                   place_kernels: bool = True,
                   validate: bool = True,
                   verify: bool = False) -> PassPipeline:
    """Map optimization flags (a planner ``Plan`` or user choices) onto a
    pass configuration.  Order mirrors the paper's rewrite order: locality
    first (lookup fusion feeds dispatch), then replication, then fusion
    (boundary-aware when locality is on), then XLA lowering of whatever
    fusion produced (batched vmap-over-rows lowering unless
    ``batched_lowering=False``).

    ``plan_config`` (a ``repro.profiling.optimizer.PlanConfig``) threads
    the SLO optimizer's per-node choices in: compile-time stamps via
    ``ApplyPlanConfigPass`` and per-op bucket/lowering overrides on
    ``LowerJaxChainsPass``."""
    passes: List[Pass] = []
    if locality:
        passes.append(FuseLookupsPass())
    if plan_config is not None:
        passes.append(ApplyPlanConfigPass(plan_config))
    if place_kernels:
        # before replication/fusion: the placed (Pallas-twin) steps flow
        # through those passes — and into the lowered chain bodies — the
        # normal way; after apply-config so placement overrides are seen
        passes.append(PlaceKernelsPass())
    if competitive_exec:
        passes.append(CompetitivePass(default_replicas=default_replicas))
    elif plan_config is not None and plan_config.replica_overrides():
        # the config names specific ops to replicate: default_replicas=0
        # keeps high_variance-hinted ops the optimizer did NOT propose
        # from being silently expanded too
        passes.append(CompetitivePass(default_replicas=0))
    if fusion:
        passes.append(FuseChainsPass(preserve_lookup_boundaries=locality))
    if jit_fusion and (fusion or plan_config is not None):
        # a config-driven compile must not silently drop the config's
        # lowering/bucket overrides just because fusion is off (a replan
        # recompile exists precisely to realize them): without fusion
        # there are no Fuse nodes, so lower bare gpu maps too (min_ops=1)
        lower = LowerJaxChainsPass(batched=batched_lowering,
                                   min_ops=2 if fusion else 1)
        if plan_config is not None:
            lower.bucket_overrides = plan_config.bucket_overrides()
            lower.batched_overrides = plan_config.batched_overrides()
        passes.append(lower)
    return PassPipeline(passes, validate=validate, verify=verify)
