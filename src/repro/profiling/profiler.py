"""Offline batch-sweep profiler: the *measure* step of the
measure -> model -> plan -> replan loop (InferLine-style per-operator
profiles over a Cloudflow plan).

``profile_plan`` sweeps every ``PhysicalOp`` of a compiled plan across
batch sizes (the same power-of-two buckets ``BatchedJittedFuse`` pads to)
and emits an :class:`OpLatencyCurve` per op — mean/p99/CV whole-batch
latency per bucket plus output payload bytes.  For batched-lowered chains
the per-row executable is timed separately (``per_row_s``), which is what
lets the optimizer pick batched-vs-per-row lowering from data instead of
heuristics.

``profile_flow_curves`` is the same sweep over a *logical* ``Dataflow``
(keyed by flow node id) — it replaces the ad-hoc single-sample loop the
cost-based planner used to carry (``repro.core.planner.profile_flow`` now
routes through it).

Curves serialize to/from plain JSON (:class:`FlowProfile`), so an offline
profile persists across processes and the online controller can refresh
the same curves from live ``ChainProfile`` measurements.
"""
from __future__ import annotations

import dataclasses
import json
import statistics
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core import operators as ops
from repro.core.ir import SOURCE_ID, PhysicalPlan
from repro.core.table import DeviceTable, Row, Table
from repro.runtime.netmodel import nbytes

try:  # keep importable without jax (profiling then skips device syncs)
    import jax
except Exception:  # pragma: no cover
    jax = None

#: default batch sizes swept per op — aligned with the lowering's
#: power-of-two padding buckets so the curve measures the shapes the
#: batched executable will actually run.
DEFAULT_SWEEP: Tuple[int, ...] = (1, 2, 4, 8, 16)


@dataclasses.dataclass
class BucketStats:
    """Whole-batch latency stats at one swept batch size."""
    mean_s: float
    p99_s: float
    cv: float
    runs: int
    out_bytes: int          # payload bytes of the whole output at this size

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "BucketStats":
        return cls(mean_s=float(d["mean_s"]), p99_s=float(d["p99_s"]),
                   cv=float(d["cv"]), runs=int(d["runs"]),
                   out_bytes=int(d["out_bytes"]))


@dataclasses.dataclass
class OpLatencyCurve:
    """One operator's measured latency curve across batch sizes.

    ``buckets[b]`` is the whole-batch cost of serving ``b`` rows in one
    invocation; ``per_row_s`` is the measured seconds/row of the *un*
    batched (per-row executable / interpreted) path, when it was measured
    separately — ``None`` means the op has a single execution mode and
    ``buckets[1]`` is the per-row cost.
    """
    key: int
    name: str
    buckets: Dict[int, BucketStats] = dataclasses.field(default_factory=dict)
    per_row_s: Optional[float] = None

    # -- queries -------------------------------------------------------------
    def _bucket_for(self, b: int) -> Optional[int]:
        measured = sorted(self.buckets)
        if not measured:
            return None
        for m in measured:
            if m >= b:
                return m
        return measured[-1]

    def service_s(self, b: int) -> float:
        """Modeled whole-batch service time for ``b`` rows: the measured
        cost at the smallest bucket >= b (batched execution pads to the
        bucket, so that IS what a b-row batch costs); past the largest
        measured bucket, scale linearly."""
        m = self._bucket_for(b)
        if m is None:
            return 0.0
        st = self.buckets[m]
        return st.mean_s if m >= b else st.mean_s * (b / m)

    def p99_s(self, b: int) -> float:
        m = self._bucket_for(b)
        if m is None:
            return 0.0
        st = self.buckets[m]
        return st.p99_s if m >= b else st.p99_s * (b / m)

    def row_s(self, b: int = 1) -> float:
        """Per-row cost on the un-batched path (falls back to bucket 1)."""
        if self.per_row_s is not None:
            return self.per_row_s
        return self.service_s(1)

    def out_bytes_per_row(self, b: int = 1) -> float:
        m = self._bucket_for(b)
        if m is None:
            return 0.0
        return self.buckets[m].out_bytes / max(1, m)

    def cv(self, b: int = 1) -> float:
        m = self._bucket_for(b)
        return self.buckets[m].cv if m is not None else 0.0

    def crossover_rows(self, max_n: int = 1024) -> Optional[int]:
        """Smallest n where the batched path is measured to beat n per-row
        dispatches — the ONE crossover rule the live router also uses."""
        from repro.core.lowering import crossover_from_costs
        return crossover_from_costs(
            self.per_row_s,
            {b: st.mean_s for b, st in self.buckets.items()}, max_n)

    # -- live refresh --------------------------------------------------------
    def merge_chain_profile(self, prof) -> bool:
        """Fold a live ``ChainProfile`` (or its ``to_dict`` form) into the
        curve: measured EWMAs replace the offline means, keeping each
        bucket's measured tail ratio.  Returns True if anything changed —
        the controller uses this to know its model went stale."""
        d = prof.to_dict() if hasattr(prof, "to_dict") else dict(prof)
        changed = False
        pr = d.get("per_row_s")
        if pr is not None and pr != self.per_row_s:
            self.per_row_s = float(pr)
            changed = True
        for b, s in (d.get("batched_s") or {}).items():
            b, s = int(b), float(s)
            old = self.buckets.get(b)
            if old is None:
                # a bucket the offline sweep never measured: inherit the
                # payload/CV shape from the nearest measured bucket
                # (zeroed out_bytes would erase the estimator's edge
                # transfer cost for any batch resolving here)
                near_b = min(self.buckets,
                             key=lambda m: abs(m - b)) \
                    if self.buckets else None
                if near_b is not None:
                    near = self.buckets[near_b]
                    out_bytes = int(near.out_bytes * b / max(1, near_b))
                    cv, tail = near.cv, max(
                        near.p99_s / near.mean_s if near.mean_s > 0
                        else 1.5, 1.0)
                else:
                    out_bytes, cv, tail = 0, 0.0, 1.5
                self.buckets[b] = BucketStats(
                    mean_s=s, p99_s=tail * s, cv=cv, runs=0,
                    out_bytes=out_bytes)
                changed = True
            elif abs(old.mean_s - s) > 1e-12:
                tail = old.p99_s / old.mean_s if old.mean_s > 0 else 1.5
                old.p99_s = s * tail
                old.mean_s = s
                changed = True
        return changed

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {"key": self.key, "name": self.name,
                "per_row_s": self.per_row_s,
                "buckets": {str(b): st.to_dict()
                            for b, st in sorted(self.buckets.items())}}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "OpLatencyCurve":
        pr = d.get("per_row_s")
        return cls(key=int(d["key"]), name=str(d.get("name", "")),
                   per_row_s=float(pr) if pr is not None else None,
                   buckets={int(b): BucketStats.from_dict(st)
                            for b, st in (d.get("buckets") or {}).items()})


@dataclasses.dataclass
class FlowProfile:
    """All of a plan's (or flow's) curves plus sweep metadata; the unit of
    persistence (``save``/``load``) and the estimator's input."""
    curves: Dict[int, OpLatencyCurve] = dataclasses.field(default_factory=dict)
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def curve(self, key: int) -> Optional[OpLatencyCurve]:
        return self.curves.get(key)

    def to_dict(self) -> Dict[str, Any]:
        return {"meta": dict(self.meta),
                "curves": {str(k): c.to_dict()
                           for k, c in sorted(self.curves.items())}}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "FlowProfile":
        return cls(meta=dict(d.get("meta") or {}),
                   curves={int(k): OpLatencyCurve.from_dict(c)
                           for k, c in (d.get("curves") or {}).items()})

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "FlowProfile":
        with open(path) as f:
            return cls.from_dict(json.load(f))


class ProfileCtx:
    """Execution context for profiling runs: KVS lookups resolve locally
    (no cache client, no network charge)."""

    def __init__(self, kvs=None):
        self.kvs = kvs

    def kvs_get(self, key):
        return self.kvs.get(key, charge=False)


# ---------------------------------------------------------------------------
# sweep machinery
# ---------------------------------------------------------------------------

def _replicate(sample: Table, b: int) -> Table:
    """A fresh b-row table cycling the sample's rows (new row ids — the
    sweep must not alias row identity across batch sizes)."""
    src = sample.rows or [Row((None,) * len(sample.schema))]
    t = Table(sample.schema, grouping=sample.grouping)
    t.rows = [Row(src[i % len(src)].values) for i in range(b)]
    return t


def _sync(out) -> None:
    """Block until device work behind ``out`` is done — async backends
    return immediately and an unsynced timing would undercount."""
    if jax is None:
        return
    try:
        if isinstance(out, DeviceTable):
            jax.block_until_ready(out.columns)
        elif isinstance(out, Table):
            vals = [v for r in out.rows for v in r.values
                    if isinstance(v, jax.Array)]
            if vals:
                jax.block_until_ready(vals)
    except Exception:
        pass


def _stats(samples: List[float], out_bytes: int) -> BucketStats:
    mean = statistics.mean(samples)
    cv = (statistics.stdev(samples) / mean) if (len(samples) > 1 and mean > 0) \
        else 0.0
    return BucketStats(mean_s=mean,
                       p99_s=float(np.percentile(np.asarray(samples), 99)),
                       cv=cv, runs=len(samples), out_bytes=out_bytes)


def _timed_apply(apply: Callable, tables: List[Table], ctx) -> Tuple[float, Any]:
    t0 = time.perf_counter()
    out = apply(tables, ctx)
    _sync(out)
    return time.perf_counter() - t0, out


def _sweep_graph(node_iter: Callable[[], Iterable[Tuple[int, str, Any,
                                                        List[int]]]],
                 sample: Table, *, batch_sizes: Tuple[int, ...],
                 runs: int, warmup: int, kvs) -> FlowProfile:
    """The shared sweep core.  ``node_iter`` yields topologically sorted
    ``(key, name, op, input_keys)`` records (``SOURCE_ID`` = the input).
    For each batch size the graph is executed ``warmup + runs`` times;
    every op application is timed individually, propagating real
    intermediate results downstream (so each op is measured on the data it
    would actually see)."""
    ctx = ProfileCtx(kvs)
    curves: Dict[int, OpLatencyCurve] = {}
    per_row_samples: Dict[int, List[float]] = {}
    for b in batch_sizes:
        src = _replicate(sample, b)
        stats: Dict[int, List[float]] = {}
        sizes: Dict[int, int] = {}
        for it in range(warmup + runs):
            timed = it >= warmup
            results: Dict[int, Any] = {SOURCE_ID: src}
            for key, name, op, input_keys in node_iter():
                ins = [results[i] for i in input_keys]
                dt, out = _timed_apply(lambda ts, c: op.apply(ts, c),
                                       ins, ctx)
                results[key] = out
                if timed:
                    stats.setdefault(key, []).append(dt)
                    sizes[key] = nbytes(out)
                # batched-lowered chains: time the per-row executable too
                # (JittedFuse.apply on the same instance) so the optimizer
                # can compare the two modes; only once, at the largest
                # swept size, where per-row cost per row is most stable
                if timed and b == max(batch_sizes) and len(src.rows) > 0 \
                        and _has_per_row_path(op):
                    try:
                        from repro.core.lowering import JittedFuse
                        dt2, _ = _timed_apply(
                            lambda ts, c: JittedFuse.apply(op, ts, c),
                            ins, ctx)
                        per_row_samples.setdefault(key, []).append(
                            dt2 / len(ins[0].rows))
                    except Exception:
                        pass
            for key, name, op, _ in node_iter():
                if key not in curves:
                    curves[key] = OpLatencyCurve(key=key, name=name)
        for key, samples in stats.items():
            curves[key].buckets[b] = _stats(samples, sizes.get(key, 0))
    for key, samples in per_row_samples.items():
        curves[key].per_row_s = statistics.mean(samples)
    return FlowProfile(curves=curves,
                       meta={"batch_sizes": list(batch_sizes),
                             "runs": runs, "warmup": warmup,
                             "sample_rows": len(sample.rows)})


def _has_per_row_path(op) -> bool:
    from repro.core.lowering import BatchedJittedFuse
    return isinstance(op, BatchedJittedFuse)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def profile_plan(plan: PhysicalPlan, sample: Table, *,
                 batch_sizes: Tuple[int, ...] = DEFAULT_SWEEP,
                 runs: int = 3, warmup: int = 1, kvs=None) -> FlowProfile:
    """Sweep every op of a compiled ``PhysicalPlan`` across batch sizes.
    Curve keys are plan op ids, matching ``PlanConfig``/estimator keys."""
    plan.validate()

    def node_iter():
        for o in plan.ops:
            yield o.op_id, o.op.name, o.op, list(o.inputs)

    fp = _sweep_graph(node_iter, sample, batch_sizes=tuple(batch_sizes),
                      runs=runs, warmup=warmup, kvs=kvs)
    fp.meta["kind"] = "plan"
    return fp


def profile_flow_curves(flow, sample: Table, *,
                        batch_sizes: Optional[Tuple[int, ...]] = None,
                        runs: int = 3, warmup: int = 0,
                        kvs=None) -> FlowProfile:
    """Sweep a *logical* ``Dataflow`` (curve keys = flow node ids).  The
    default sweep is the sample's own size — exactly what the cost-based
    planner's fuse/competitive/locality decisions need — pass explicit
    ``batch_sizes`` for a full curve."""
    flow.typecheck()
    if batch_sizes is None:
        batch_sizes = (max(1, len(sample.rows)),)

    def node_iter():
        for n in flow.sorted_nodes():
            if n.op is None:
                continue
            yield (n.id, n.op.name, n.op,
                   [u.id if u.op is not None else SOURCE_ID
                    for u in n.upstreams])

    fp = _sweep_graph(node_iter, sample, batch_sizes=tuple(batch_sizes),
                      runs=runs, warmup=warmup, kvs=kvs)
    fp.meta["kind"] = "flow"
    return fp


def seed_from_model_ops(plan: PhysicalPlan, *,
                        batch_sizes: Tuple[int, ...] = DEFAULT_SWEEP
                        ) -> FlowProfile:
    """Build a ``FlowProfile`` from the plan's ``ModelOp`` cost hooks:
    each hook measures its model stage natively batched at every swept
    size, and an op's curve is the sum of its (possibly fused) model-stage
    hooks per bucket.  This is how real model profiles enter the
    measure->model->plan loop without a full graph sweep — curves for the
    plan's non-model ops are left to ``profile_plan``/``refresh_from_plan``
    (``SLOController.refresh_profile`` merges live chain measurements into
    whatever this seeds)."""
    curves: Dict[int, OpLatencyCurve] = {}
    for o in plan.ops:
        subs = list(getattr(o.op, "ops", None) or [o.op])
        hooked = [s for s in subs
                  if isinstance(s, ops.ModelOp) and s.cost_hook is not None]
        if not hooked:
            continue
        curve = OpLatencyCurve(key=o.op_id, name=o.op.name)
        for b in batch_sizes:
            mean = p99 = cv = 0.0
            runs, out_bytes = 0, 0
            for s in hooked:
                d = s.cost_hook(b)
                mean += float(d["mean_s"])
                p99 += float(d["p99_s"])
                cv = max(cv, float(d["cv"]))
                runs = int(d["runs"]) if not runs \
                    else min(runs, int(d["runs"]))
                out_bytes = int(d["out_bytes"])   # last stage's payload
            curve.buckets[b] = BucketStats(mean_s=mean, p99_s=p99, cv=cv,
                                           runs=runs, out_bytes=out_bytes)
        curves[o.op_id] = curve
    return FlowProfile(curves=curves,
                       meta={"kind": "model-op-seed",
                             "batch_sizes": list(batch_sizes)})


def refresh_from_plan(profile: FlowProfile, plan: PhysicalPlan) -> bool:
    """Fold every live ``ChainProfile`` the plan's lowered ops have
    accumulated into the offline curves (the controller's measure step).
    Returns True if any curve moved."""
    changed = False
    for o in plan.ops:
        prof_fn = getattr(o.op, "profile", None)
        if prof_fn is None:
            continue
        curve = profile.curves.get(o.op_id)
        if curve is None:
            curve = profile.curves[o.op_id] = OpLatencyCurve(
                key=o.op_id, name=o.op.name)
        try:
            if curve.merge_chain_profile(prof_fn()):
                changed = True
        except Exception:
            continue
    return changed
