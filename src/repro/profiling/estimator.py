"""DAG end-to-end latency estimator: the *model* step of the
measure -> model -> plan -> replan loop.

Per node the model is a batch-service queue: requests arrive at rate
``lambda``, coalesce into batches of ``b`` (paying a batch-formation wait
bounded by the batcher window), and the batches are served by ``c``
replicas whose service time comes from the node's measured
:class:`~repro.profiling.profiler.OpLatencyCurve`.  Queueing delay uses
the M/M/c Erlang-C waiting-time formula on *batch* arrivals — the same
shape InferLine's pipeline model uses, kept deliberately coarse (the
benchmark reports the estimator's relative error against measured serve
latencies, which is the honest way to know how coarse).

End-to-end latency is a critical-path walk over the ``PhysicalPlan`` DAG:
node completion = combine(inputs) + edge cost (invocation overhead +
payload transfer) + node latency, where combine is ``max`` for ordinary
joins and ``min`` for wait-for-any (competitive) nodes — competitive
replication suppresses the tail, so wait-any nodes also use the mean
curve in the p99 walk.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from repro.core.ir import SOURCE_ID, PhysicalPlan
from repro.profiling.profiler import FlowProfile, OpLatencyCurve
from repro.runtime.netmodel import NetModel

#: fallback service time for ops with no curve (pass-through anyof nodes
#: the competitive pass added, ops the profiler never saw): small but not
#: zero, so critical paths stay ordered sensibly.
DEFAULT_SERVICE_S = 50e-6

#: finite stand-in for "queue grows without bound" (seconds), scaled by
#: utilization so saturated configs still rank against each other.
SATURATION_PENALTY_S = 1e6


@dataclasses.dataclass
class Workload:
    """The open-loop arrival process the estimator models."""
    arrival_rate: float              # requests/s entering the flow
    request_rows: int = 1            # rows per request


@dataclasses.dataclass
class FaultStats:
    """Measured fault pressure (events/sec from the runtime's windowed
    fault counters) the estimator folds into its tail prediction: a
    request caught by a retry or a crash requeue pays roughly one extra
    service time plus the detector's reaction, so under fault pressure
    the clean-path p99 is an underestimate exactly when the controller
    most needs it to be honest."""
    crash_rate: float = 0.0          # executor crashes/s
    wedge_rate: float = 0.0          # wedge detections/s
    retry_rate: float = 0.0          # transient retries/s
    requeue_rate: float = 0.0        # items requeued by failover/s
    detection_s: float = 0.0         # detector reaction time (interval)

    def disturbed_fraction(self, arrival_rate: float) -> float:
        """Fraction of requests whose attempt is disturbed (retried or
        requeued) — the probability mass that pays the inflated path."""
        lam = max(arrival_rate, 1e-9)
        return min(1.0, (self.retry_rate + self.requeue_rate) / lam)

    def inflate_p99(self, p99_s: float, arrival_rate: float) -> float:
        """Predicted p99 with fault pressure folded in: the disturbed
        fraction re-pays the whole clean path (re-execution) plus the
        failure-detection delay.  Zero rates leave the estimate exactly
        unchanged."""
        p = self.disturbed_fraction(arrival_rate)
        if p <= 0.0:
            return p99_s
        return p99_s * (1.0 + p) + p * self.detection_s


def erlang_c(c: int, a: float) -> float:
    """P(wait) for an M/M/c queue with offered load ``a`` erlangs
    (``a = lambda / mu``).  Returns 1.0 at/above saturation."""
    if c <= 0 or a >= c:
        return 1.0
    if a <= 0:
        return 0.0
    s = sum(a ** k / math.factorial(k) for k in range(c))
    last = a ** c / (math.factorial(c) * (1.0 - a / c))
    return last / (s + last)


@dataclasses.dataclass
class NodeEstimate:
    op_id: int
    batch: int                       # modeled batch size (rows)
    replicas: int                    # modeled service replicas (M/M/c c)
    service_s: float                 # whole-batch service time
    service_p99_s: float
    batch_wait_s: float              # batch-formation wait (full window)
    queue_wait_s: float              # M/M/c mean wait for a free replica
    queue_p99_s: float
    rho: float                       # utilization (load per replica)
    mean_s: float                    # per-request mean at this node
    p99_s: float                     # per-request p99 at this node
    feasible: bool                   # rho < 1


@dataclasses.dataclass
class LatencyEstimate:
    mean_s: float
    p99_s: float
    feasible: bool
    nodes: Dict[int, NodeEstimate]
    critical_path: List[int]         # op ids on the p99-critical path

    def meets(self, slo_p99_s: float) -> bool:
        return self.feasible and self.p99_s <= slo_p99_s

    def summary(self) -> Dict[str, object]:
        return {"mean_ms": self.mean_s * 1e3, "p99_ms": self.p99_s * 1e3,
                "feasible": self.feasible,
                "critical_path": list(self.critical_path)}


class LatencyEstimator:
    """Maps (plan, per-node config, workload) -> predicted latency."""

    def __init__(self, profile: FlowProfile,
                 net: Optional[NetModel] = None,
                 fault: Optional[FaultStats] = None):
        self.profile = profile
        self.net = net or NetModel()
        # measured fault pressure; when set, estimate() inflates the p99
        # walk by the disturbed-request fraction (ROADMAP: fault-aware
        # estimator)
        self.fault = fault

    # -- per-node model ------------------------------------------------------
    def node_estimate(self, op_id: int, cfg, wl: Workload,
                      curve: Optional[OpLatencyCurve] = None) -> NodeEstimate:
        """``cfg`` duck-types ``repro.profiling.optimizer.NodeConfig``:
        ``max_batch``, ``batch_wait_ms``, ``batched_lowering``,
        ``target_replicas``, ``competitive_replicas``."""
        curve = curve or self.profile.curve(op_id)
        lam = max(wl.arrival_rate, 1e-9)
        rows = max(1, wl.request_rows)
        max_batch = max(1, int(getattr(cfg, "max_batch", 1) or 1))
        batched = bool(getattr(cfg, "batched_lowering", True))
        c = max(1, int(getattr(cfg, "target_replicas", 1) or 1))
        window = max(0.0, float(getattr(cfg, "batch_wait_ms", 0.0)) / 1e3)

        # expected coalesced batch: what the window can accumulate at this
        # arrival rate, capped by max_batch
        b_req = max(1, min(max_batch, int(lam * window) + 1))
        b_rows = b_req * rows
        batch_wait = 0.0 if b_req <= 1 else min(window, (b_req - 1) / lam)

        if curve is None:
            service = DEFAULT_SERVICE_S
            service_p99 = DEFAULT_SERVICE_S
        elif batched:
            service = curve.service_s(b_rows)
            service_p99 = curve.p99_s(b_rows)
        else:
            service = curve.row_s() * b_rows
            service_p99 = service * (curve.p99_s(1) /
                                     max(curve.service_s(1), 1e-12)
                                     if curve.buckets else 1.0)
        service = max(service, 1e-9)
        service_p99 = max(service_p99, service)

        lam_batches = lam / b_req
        a = lam_batches * service            # offered erlangs
        rho = a / c
        feasible = rho < 1.0
        if feasible:
            pw = erlang_c(c, a)
            # M/M/c: Wq = P(wait) / (c*mu - lambda); tail is exponential
            # with the same rate, so p99 wait = ln(P(wait)/0.01) / rate.
            # Allen-Cunneen correction (Ca^2 + Cs^2)/2 with Poisson
            # arrivals (Ca=1) and the curve's measured service CV: mostly
            # deterministic services (sleep-bound compute) queue about
            # half as much as the exponential model says
            cs2 = (curve.cv(b_rows) ** 2) if curve is not None else 1.0
            ac = (1.0 + min(cs2, 4.0)) / 2.0
            drain = c / service - lam_batches
            queue = ac * pw / drain
            queue_p99 = (ac * math.log(pw / 0.01) / drain) \
                if pw > 0.01 else 0.0
        else:
            # saturated: a huge-but-FINITE penalty ordered by utilization,
            # so the optimizer's greedy search can still rank saturated
            # configs (inf - inf comparisons would stall the ascent) and
            # always walks downhill toward stability first
            queue = queue_p99 = SATURATION_PENALTY_S * rho

        # competitive replication (wait-any over k copies) suppresses the
        # service tail: the fastest of k draws sits near the mean
        if int(getattr(cfg, "competitive_replicas", 0) or 0) >= 2:
            service_p99 = service

        mean = batch_wait / 2.0 + queue + service
        p99 = batch_wait + queue_p99 + service_p99
        return NodeEstimate(op_id=op_id, batch=b_rows, replicas=c,
                            service_s=service, service_p99_s=service_p99,
                            batch_wait_s=batch_wait, queue_wait_s=queue,
                            queue_p99_s=queue_p99, rho=rho, mean_s=mean,
                            p99_s=p99, feasible=feasible)

    # -- DAG model -----------------------------------------------------------
    def estimate(self, plan: PhysicalPlan, config, wl: Workload) \
            -> LatencyEstimate:
        """``config`` duck-types ``PlanConfig``: ``.node(op_id)`` or a
        ``nodes`` dict of per-op configs (missing ops get defaults)."""
        get_node = getattr(config, "node", None)
        nodes_map = getattr(config, "nodes", {}) if get_node is None else None

        class _Default:
            max_batch = 1
            batch_wait_ms = 0.0
            batched_lowering = True
            target_replicas = 1
            competitive_replicas = 0

        def cfg_for(op_id: int):
            if get_node is not None:
                return get_node(op_id)
            return nodes_map.get(op_id, _Default)

        estimates: Dict[int, NodeEstimate] = {}
        done_mean: Dict[int, float] = {SOURCE_ID: 0.0}
        done_p99: Dict[int, float] = {SOURCE_ID: 0.0}
        pred: Dict[int, Optional[int]] = {SOURCE_ID: None}
        feasible = True
        for o in plan.ops:
            ne = estimates[o.op_id] = self.node_estimate(
                o.op_id, cfg_for(o.op_id), wl)
            feasible = feasible and ne.feasible
            in_mean, in_p99, best_in = 0.0, 0.0, None
            arrivals = []
            for i in o.inputs:
                up_curve = self.profile.curve(i)
                edge = self.net.invoke_overhead_s * self.net.scale
                if up_curve is not None:
                    edge += self.net.transfer_time(
                        up_curve.out_bytes_per_row() * ne.batch)
                arrivals.append((done_mean[i] + edge, done_p99[i] + edge, i))
            if arrivals:
                # wait-any fires on the FIRST completed input; ordinary
                # nodes wait for all of them
                pick = min if o.wait_any else max
                in_mean, in_p99, best_in = pick(arrivals)
            done_mean[o.op_id] = in_mean + ne.mean_s
            done_p99[o.op_id] = in_p99 + ne.p99_s
            pred[o.op_id] = best_in
        out = plan.output_id
        path: List[int] = []
        cur: Optional[int] = out
        while cur is not None and cur != SOURCE_ID:
            path.append(cur)
            cur = pred.get(cur)
        p99 = done_p99[out]
        if self.fault is not None:
            p99 = self.fault.inflate_p99(p99, wl.arrival_rate)
        return LatencyEstimate(mean_s=done_mean[out], p99_s=p99,
                               feasible=feasible, nodes=estimates,
                               critical_path=list(reversed(path)))
