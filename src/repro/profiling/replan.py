"""Zero-downtime blue/green replanning: the *replan* step the controller
escalates to when the fix needs compile-time changes.

The paper's position (and InferLine's) is that a serving dataflow must be
re-optimizable without taking traffic down.  The controller's hot-apply
path covers runtime-safe knobs; everything else — lowering mode flips,
placement, competitive topology, bucket sets — needs a recompile, and a
naive re-registration would serve cold executables to live traffic (and,
before generation-keyed runtime state, corrupt the old deployment's
batchers).  :class:`BlueGreenReplanner` does it safely:

1. **compile** — ``compile_flow(plan_config=…, register=False)`` builds
   the green plan + DAG entirely off the serving path; blue keeps serving.
2. **warm** — :func:`warm_deployment` walks the green DAG topologically at
   every padding bucket size with the exec-path router bypassed
   (``forced_batched_routing``), tracing every (chain, bucket, variant)
   executable through the shared ``EXECUTABLE_CACHE`` before any traffic
   can reach it.  Chains unchanged from blue hit the cache (zero new
   traces); changed ones pay their traces here, not on a request.
3. **canary-verify** — a few requests driven through the green DAG via
   ``Runtime.call_dag_object`` (not traffic-visible, not recorded in the
   controller's metric series), outputs checked against the BLUE
   generation's output for the same input — a replan changes execution
   strategy, never semantics, so the generations must agree.  (Blue as
   reference keeps the check on warm executables; ``reference="local"``
   swaps in the logical flow's interpreted ground truth, which pays
   first-time eager-op compiles and is kept for offline use.)  A mismatch
   or error ABORTS the replan; blue stays live and untouched.
4. **swap** — ``Runtime.register_dag`` atomically routes new ``call_dag``
   requests to green while in-flight executions finish on blue; blue's
   batchers retire when their generation's last request completes and
   close once quiescent.  The proposal's runtime knobs (batcher windows,
   autoscaler targets) are applied to green; hot-applied batch config
   carries over automatically where node names match
   (``Runtime._node_batch_cfg`` is keyed logically), and live router
   state (``ChainProfile``) carries over wherever chain signatures match
   (the executable cache keys by signature, not by deployment).
5. **confirm** — the controller's next tick measures the post-swap config
   against the SLO (``post_replan_confirm`` in the event detail).

The ``DeployedFlow`` handle is updated in place, so every holder — the
controller, benchmarks, user code — follows the swap transparently.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.compiler import compile_flow
from repro.core.lowering import (EXECUTABLE_CACHE, BatchedJittedFuse,
                                 forced_batched_routing)
from repro.core.table import DeviceTable, Table
from repro.profiling.profiler import ProfileCtx, _replicate


@dataclasses.dataclass
class ReplanReport:
    """What one blue/green replan attempt did, phase by phase."""
    dag_name: str
    ok: bool = False
    phase: str = "init"        # compile | warm | canary | swap | done
    reason: str = ""           # why it aborted, when it did
    blue_generation: int = 0
    green_generation: int = 0
    warm: Dict[str, Any] = dataclasses.field(default_factory=dict)
    canary: Dict[str, Any] = dataclasses.field(default_factory=dict)
    timings_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    notes: List[str] = dataclasses.field(default_factory=list)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# warm: trace every (chain, bucket) executable before traffic arrives
# ---------------------------------------------------------------------------

def _walk_sizes(runtime, deployed, extra_rows=()) -> List[int]:
    """Row counts the warm walk must cover: every configured padding
    bucket, PLUS the bucket a full batcher merge pads to — the batcher
    coalesces up to ``max_batch`` single-row requests, and past the
    largest configured bucket padding doubles, so a max-size batch can
    land on a bucket outside the configured set."""
    from repro.core.lowering import bucket_rows
    plan, dag = deployed.plan, deployed.dag
    by_op_id = {n.plan_op_id: n for n in dag.nodes.values()}
    sizes = set(extra_rows)
    for o in plan.ops:
        op = o.op
        if not isinstance(op, BatchedJittedFuse):
            continue
        sizes.update(op.bucket_sizes)
        node = by_op_id.get(o.op_id)
        if node is not None and node.batching:
            cfg = runtime._node_batch_cfg.get((dag.name, node.name), {})
            mb = int(cfg.get("max_batch", runtime.max_batch))
            sizes.add(bucket_rows(mb, op.bucket_sizes))
    return sorted(sizes or {1})


def _observed_buckets(runtime, dag, coverage) -> List[int]:
    """Buckets live traffic actually landed on, most-frequent first: the
    row-count histogram read from the runtime's ``batch/<dag>/.../size``
    metric series (prefix-matched — node names change across lowering
    flips, and a green DAG shares its blue predecessor's name, so blue's
    traffic shape steers green's warm order).  Each observed batch size
    maps to the padding bucket that would serve it."""
    from repro.core.lowering import bucket_rows
    prefix = f"batch/{dag.name}/"
    hist: Dict[int, int] = {}
    snapshot = getattr(runtime, "metrics_snapshot", lambda: {})()
    for key, series in snapshot.items():
        if not (key.startswith(prefix) and key.endswith("/size")):
            continue
        for v in series:
            b = bucket_rows(max(1, int(v)), coverage)
            hist[b] = hist.get(b, 0) + 1
    return [b for b, _ in sorted(hist.items(),
                                 key=lambda kv: (-kv[1], kv[0]))]


def warm_deployment(runtime, deployed, sample: Table,
                    buckets: Optional[List[int]] = None,
                    extra_rows=()) -> Dict[str, Any]:
    """Pre-trace a compiled deployment's executables through the shared
    ``EXECUTABLE_CACHE``: walk the DAG topologically once per padding
    bucket size, feeding each node its upstream's real output, with the
    exec-path router bypassed so the vmapped executable is traced even at
    sizes the live router would send per-row.  A 1-row walk additionally
    warms the per-row executables (the live singleton path).

    Walking the *runtime* node functions — not the bare ops — matters:
    they capture the device-residency flags (``emit_device``/donation), so
    exactly the executable variants live traffic will request get traced.

    Returns trace/entry accounting: ``fresh_traces`` is how many XLA
    traces this warm paid so that post-swap traffic pays zero.  Coverage
    assumes single-row requests (the serving norm): a merge of multi-row
    requests can exceed ``max_batch`` rows and land on a bucket beyond the
    warmed set — pass those sizes via ``extra_rows``."""
    dag = deployed.dag
    plan = deployed.plan
    if buckets is None:
        buckets = _walk_sizes(runtime, deployed, extra_rows)
    # warm the buckets live traffic is actually hitting FIRST — if the
    # swap races the warm walk (or the walk aborts), the executables most
    # likely to be requested next are already traced; the remainder of
    # the coverage set follows so nothing is left cold
    observed = _observed_buckets(runtime, dag, buckets)
    buckets = ([b for b in observed if b in set(buckets)]
               + [b for b in buckets if b not in set(observed)])
    ctx = ProfileCtx(getattr(runtime, "kvs", None))
    before = EXECUTABLE_CACHE.traces()
    stats_before = EXECUTABLE_CACHE.stats()
    errors: List[str] = []
    chain_ops = [o.op for o in plan.ops]
    with forced_batched_routing(chain_ops):
        for b in buckets:
            src = _replicate(sample, b)
            results: Dict[str, Any] = {}
            for node in dag.topo():
                if node.deps:
                    ins = [results.get(d) for d in node.deps]
                    if node.wait_any:
                        ins = [next((t for t in ins if t is not None),
                                    None)]
                else:
                    ins = [src]
                if any(t is None for t in ins):
                    continue        # upstream failed; best-effort walk
                try:
                    results[node.name] = node.fn(list(ins), ctx)
                except Exception as e:      # warm is best-effort; the
                    errors.append(          # canary judges correctness
                        f"{node.name}@bucket{b}: {type(e).__name__}: {e}")
    after = EXECUTABLE_CACHE.traces()
    stats_after = EXECUTABLE_CACHE.stats()
    return {
        "buckets": list(buckets),
        "observed": observed,
        "traces_before": before,
        "traces_after": after,
        "fresh_traces": after - before,
        "fresh_entries": stats_after["entries"] - stats_before["entries"],
        "errors": errors,
    }


# ---------------------------------------------------------------------------
# canary: green must reproduce the logical flow's results before it serves
# ---------------------------------------------------------------------------

def _rows_match(got: Table, want: Table, rtol: float) -> Optional[str]:
    if len(got.rows) != len(want.rows):
        return f"row count {len(got.rows)} != {len(want.rows)}"
    for i, (g, w) in enumerate(zip(got.rows, want.rows)):
        if len(g.values) != len(w.values):
            return f"row {i}: arity {len(g.values)} != {len(w.values)}"
        for j, (gv, wv) in enumerate(zip(g.values, w.values)):
            try:
                ga, wa = np.asarray(gv), np.asarray(wv)
                if ga.shape != wa.shape:
                    return (f"row {i} col {j}: shape {ga.shape} "
                            f"!= {wa.shape}")
                if ga.dtype.kind in "fc" or wa.dtype.kind in "fc":
                    if not np.allclose(ga, wa, rtol=rtol, atol=1e-6):
                        return f"row {i} col {j}: values differ"
                elif not np.array_equal(ga, wa):
                    return f"row {i} col {j}: values differ"
            except Exception:
                if gv != wv:
                    return f"row {i} col {j}: values differ"
    return None


# ---------------------------------------------------------------------------
# the replanner
# ---------------------------------------------------------------------------

class BlueGreenReplanner:
    """compile → warm → canary-verify → swap, with blue serving
    throughout.  Callable, so it plugs directly into
    ``SLOController(on_replan=replanner)`` — the controller's default
    escalation path constructs one automatically.

    ``sample`` is a representative request table (used for warming and
    canaries; without it both steps are skipped, with a note — the swap
    then pays cold traces only for chains that actually changed).
    ``compile_flags`` defaults to the flags the deployment was compiled
    with (recorded on ``DeployedFlow``); an explicit-pipeline deployment
    must pass them, because PlanConfig op ids are only stable across
    recompiles with the same pass configuration."""

    def __init__(self, runtime, deployed, *, sample: Optional[Table] = None,
                 autoscaler=None, canary_requests: int = 2,
                 canary_timeout_s: float = 60.0, verify: bool = True,
                 reference: str = "blue", rtol: float = 1e-5,
                 compile_flags: Optional[dict] = None):
        self.runtime = runtime
        self.deployed = deployed
        self.sample = sample
        self.autoscaler = autoscaler
        self.canary_requests = canary_requests
        self.canary_timeout_s = canary_timeout_s
        self.verify = verify
        self.reference = reference        # "blue" | "local"
        self.rtol = rtol
        if compile_flags is None:
            compile_flags = getattr(deployed, "compile_flags", None)
        self.compile_flags = compile_flags
        self.history: List[ReplanReport] = []
        # set by a successful swap: everything needed to re-register blue
        # atomically if the confirm tick shows green missing the SLO
        self._rollback: Optional[Dict[str, Any]] = None

    #: reports kept (a controller re-escalating for hours must not grow
    #: the history without bound)
    HISTORY_CAP = 32

    def __call__(self, proposal) -> ReplanReport:
        return self.replan(proposal)

    def _phase_event(self, phase: str, t0: float, t1: float,
                     **attrs) -> None:
        """Control-plane span for one swap phase (prepare/warm/canary/
        swap/confirm/rollback) — exported on the trace's control track
        so a during-swap p99 blip is attributable to its phase."""
        tracer = getattr(self.runtime, "tracer", None)
        if tracer is None or not getattr(tracer, "enabled", False):
            return
        ce = getattr(tracer, "control_event", None)
        if ce is not None:
            ce(f"replan@{self.deployed.dag.name}", t0, t1, phase=phase,
               **attrs)

    # -- phases --------------------------------------------------------------
    def _reference(self, blue_dag, rep: ReplanReport):
        """The output green must reproduce: blue's, for the same input
        (warm executables, no compile on the hot path), or the logical
        flow's interpreted ground truth with ``reference="local"``."""
        sample = self.sample
        req = _replicate(sample, max(1, len(sample.rows)))
        try:
            if self.reference == "local":
                return self.deployed.flow.execute_local(
                    req, ProfileCtx(getattr(self.runtime, "kvs", None)))
            out = self.runtime.call_dag_object(blue_dag, req) \
                .result(timeout=self.canary_timeout_s)
            if isinstance(out, DeviceTable):
                out = out.to_table()
            return out
        except Exception as e:
            rep.canary["reference_error"] = f"{type(e).__name__}: {e}"
            return None

    def _canary(self, green, blue_dag, rep: ReplanReport) -> bool:
        sample = self.sample
        want = self._reference(blue_dag, rep)
        if want is None:
            # no reference means no verification: abort rather than swap
            # an unverified green (the documented contract — pass
            # verify=False to swap without canaries)
            rep.canary.update(requests=0, ok=False,
                              error="reference unavailable: "
                              + str(rep.canary.get("reference_error")))
            return False
        for i in range(self.canary_requests):
            req = _replicate(sample, max(1, len(sample.rows)))
            try:
                out = self.runtime.call_dag_object(green.dag, req) \
                    .result(timeout=self.canary_timeout_s)
            except Exception as e:
                rep.canary.update(requests=i + 1, ok=False,
                                  error=f"{type(e).__name__}: {e}")
                return False
            if isinstance(out, DeviceTable):
                out = out.to_table()
            mismatch = _rows_match(out, want, self.rtol)
            if mismatch is not None:
                rep.canary.update(requests=i + 1, ok=False,
                                  error=f"mismatch: {mismatch}")
                return False
        rep.canary.update(requests=self.canary_requests, ok=True)
        return True

    def replan(self, proposal) -> ReplanReport:
        """Run the full lifecycle for one proposed ``PlanConfig``.  On any
        pre-swap failure the report says why and BLUE IS UNTOUCHED; after
        the swap point the report is ``ok`` and the ``DeployedFlow``
        handle points at green."""
        rt = self.runtime
        dep = self.deployed
        blue = dep.dag
        rep = ReplanReport(dag_name=blue.name,
                           blue_generation=blue.generation)
        self.history.append(rep)
        del self.history[:-self.HISTORY_CAP]

        if self.compile_flags is None:
            rep.phase, rep.reason = "compile", \
                ("deployment compiled with an explicit pipeline; pass "
                 "compile_flags to BlueGreenReplanner")
            return rep

        # 1) compile green off the hot path (blue keeps serving)
        rep.phase = "compile"
        t0 = time.perf_counter()
        try:
            green = compile_flow(dep.flow, rt, plan_config=proposal,
                                 name=blue.name, register=False,
                                 **self.compile_flags)
        except Exception as e:
            rep.reason = f"compile failed: {type(e).__name__}: {e}"
            self._phase_event("prepare", t0, time.perf_counter(), ok=False)
            return rep
        rep.timings_s["compile"] = time.perf_counter() - t0
        rep.green_generation = green.dag.generation
        self._phase_event("prepare", t0, time.perf_counter(), ok=True,
                          green_generation=green.dag.generation)

        swapped = False
        try:
            # 2) pre-warm every (chain, bucket, variant) executable — the
            #    proposal's batcher sizes included, since a full merge
            #    pads to THEIR covering bucket, configured set or not
            rep.phase = "warm"
            t0 = time.perf_counter()
            if self.sample is not None:
                extra = {cfg.max_batch for cfg in proposal.nodes.values()
                         if cfg.max_batch > 1}
                rep.warm = warm_deployment(rt, green, self.sample,
                                           extra_rows=sorted(extra))
            else:
                rep.notes.append("no sample: warm skipped")
            rep.timings_s["warm"] = time.perf_counter() - t0
            self._phase_event("warm", t0, time.perf_counter(),
                              skipped=self.sample is None)

            # 3) canary-verify green end to end before traffic sees it
            rep.phase = "canary"
            t0 = time.perf_counter()
            if self.verify and self.sample is not None:
                if not self._canary(green, blue, rep):
                    rep.reason = ("canary failed — blue stays live: "
                                  + str(rep.canary.get("error")))
                    self._phase_event("canary", t0, time.perf_counter(),
                                      ok=False,
                                      error=str(rep.canary.get("error")))
                    return rep
            else:
                rep.notes.append("canary skipped")
            rep.timings_s["canary"] = time.perf_counter() - t0
            self._phase_event("canary", t0, time.perf_counter(), ok=True,
                              skipped=not (self.verify
                                           and self.sample is not None))

            # 4) atomic swap: new requests -> green, in-flight finish on
            #    blue, blue's batchers drain and close on quiescence
            rep.phase = "swap"
            t0 = time.perf_counter()
            blue_state = {"dag": blue, "plan": dep.plan,
                          "pass_trace": getattr(dep, "pass_trace", None)}
            rt.register_dag(green.dag, plan=green.plan)
            swapped = True
            applied = proposal.apply_runtime(rt, green.dag,
                                             autoscaler=self.autoscaler)
            rep.notes.extend(applied)
            # the handle every holder shares now IS the green deployment
            dep.plan = green.plan
            dep.dag = green.dag
            dep.pass_trace = green.pass_trace
            # keep blue resurrectable until the confirm tick passes: its
            # batchers drain but its DAG/plan stay valid, so a failed
            # confirm can swap it straight back in
            self._rollback = blue_state
            adm = getattr(rt, "admission_for", lambda _n: None)(blue.name)
            if adm is not None:
                adm.update(plan=green.plan, config=proposal)
            rep.timings_s["swap"] = time.perf_counter() - t0
            self._phase_event("swap", t0, time.perf_counter(),
                              green_generation=green.dag.generation,
                              blue_generation=blue.generation)
            rep.phase = "done"
            rep.ok = True
            return rep
        finally:
            if not swapped:
                # aborted after green existed: its canary-created
                # batchers (and their threads) must not leak — each
                # re-escalation would otherwise compile a fresh green
                # and pile up another generation's batchers
                try:
                    rt.discard_dag(green.dag)
                except Exception:
                    pass

    # -- rollback ------------------------------------------------------------
    def can_swap_back(self) -> bool:
        return self._rollback is not None

    def swap_back(self, reason: str = "") -> Optional[Dict[str, Any]]:
        """Automatic rollback: re-register the previous (blue) generation
        after a swap whose confirm tick failed.  ``register_dag`` clears
        blue's retired/draining marks atomically, so its (possibly fresh)
        batchers serve immediately; green drains and retires exactly like
        any superseded generation — zero dropped requests either way.
        Records a ``replan/rollback`` metric and returns a small report,
        or None when there is nothing to roll back to."""
        state = self._rollback
        if state is None:
            return None
        self._rollback = None
        rt = self.runtime
        dep = self.deployed
        blue_dag, blue_plan = state["dag"], state["plan"]
        rt.register_dag(blue_dag, plan=blue_plan)
        dep.plan = blue_plan
        dep.dag = blue_dag
        if state["pass_trace"] is not None:
            dep.pass_trace = state["pass_trace"]
        adm = getattr(rt, "admission_for", lambda _n: None)(blue_dag.name)
        if adm is not None:
            adm.update(plan=blue_plan)
        record = getattr(rt, "record_metric", None)
        if record is not None:
            from repro.obs import keys as okeys
            record(okeys.REPLAN_ROLLBACK, time.perf_counter())
        t_rb = time.perf_counter()
        self._phase_event("rollback", t_rb, t_rb, reason=reason,
                          restored_generation=blue_dag.generation)
        report = {"rolled_back": True, "reason": reason,
                  "dag": blue_dag.name,
                  "restored_generation": blue_dag.generation}
        if self.history:
            self.history[-1].notes.append(
                f"rolled back to gen {blue_dag.generation}: {reason}")
        return report
