"""SLO-aware configuration search: the *plan* step of the
measure -> model -> plan -> replan loop.

``propose(plan, slo_p99, arrival_rate)`` searches per-node configuration
space — batch size / padding buckets, batcher window, batched-vs-per-row
lowering, service replicas (M/M/c ``c``), competitive replication — by
querying the :class:`~repro.profiling.estimator.LatencyEstimator`, and
returns a :class:`PlanConfig`:

* per node, the (mode, batch) pair minimizing that node's modeled
  per-request p99 at the measured arrival rate (infeasible points — queue
  utilization >= 1 — are pruned, which is what forces batching on when a
  single replica can't keep up per-row);
* then a greedy InferLine-style replica ascent: while the end-to-end p99
  misses the SLO, add one replica to the critical-path node with the best
  marginal p99 reduction (re-picking its best batch at the new c);
* finally competitive replication for tail-dominated (high-CV) critical
  nodes if the SLO is still missed.

The result is consumed in three places: ``build_pipeline``/``compile_flow``
(per-op bucket/lowering/placement overrides on the pass pipeline),
``PlanConfig.apply_runtime`` (per-node batcher window + max-batch on a
*live* deployment — no re-registration), and ``Autoscaler.set_target``
(per-function replica targets).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.core.ir import PhysicalPlan
from repro.profiling.estimator import (LatencyEstimate, LatencyEstimator,
                                       Workload)
from repro.profiling.profiler import FlowProfile, profile_plan

#: candidate batch sizes when a curve has no measured buckets
_FALLBACK_BATCHES: Tuple[int, ...] = (1, 2, 4, 8, 16)


@dataclasses.dataclass
class NodeConfig:
    """One op's knobs.  ``max_batch``/``batch_wait_ms`` drive the runtime
    batcher; ``batch_buckets`` the lowering's padding; ``batched_lowering``
    picks vmapped vs per-row execution; ``target_replicas`` is the M/M/c
    service parallelism (autoscaler target); ``competitive_replicas`` the
    wait-any tail-suppression factor; ``placement`` overrides the executor
    resource class."""
    max_batch: int = 1
    batch_buckets: Tuple[int, ...] = ()
    batch_wait_ms: float = 0.0
    batched_lowering: bool = True
    target_replicas: int = 1
    competitive_replicas: int = 0
    placement: Optional[str] = None

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["batch_buckets"] = list(self.batch_buckets)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "NodeConfig":
        kw = dict(d)
        kw["batch_buckets"] = tuple(kw.get("batch_buckets") or ())
        return cls(**kw)


_DEFAULT_NODE = NodeConfig()


@dataclasses.dataclass
class PlanConfig:
    """A complete per-node configuration for one plan, keyed by plan op id
    (stable across recompiles of the same flow with the same flag set)."""
    nodes: Dict[int, NodeConfig] = dataclasses.field(default_factory=dict)
    slo_p99_s: Optional[float] = None
    arrival_rate: Optional[float] = None
    predicted: Optional[LatencyEstimate] = None
    notes: List[str] = dataclasses.field(default_factory=list)

    def node(self, op_id: int) -> NodeConfig:
        return self.nodes.get(op_id, _DEFAULT_NODE)

    # -- pass-pipeline consumption ------------------------------------------
    def bucket_overrides(self) -> Dict[int, Tuple[int, ...]]:
        return {i: c.batch_buckets for i, c in self.nodes.items()
                if c.batch_buckets}

    def batched_overrides(self) -> Dict[int, bool]:
        return {i: c.batched_lowering for i, c in self.nodes.items()}

    def placement_overrides(self) -> Dict[int, str]:
        return {i: c.placement for i, c in self.nodes.items()
                if c.placement}

    def replica_overrides(self) -> Dict[int, int]:
        return {i: c.competitive_replicas for i, c in self.nodes.items()
                if c.competitive_replicas >= 2}

    # -- runtime consumption -------------------------------------------------
    def apply_runtime(self, runtime, dag, autoscaler=None) -> List[str]:
        """Hot-apply the runtime-safe knobs to a LIVE deployment: per-node
        batcher max-batch/window, lowered-op padding buckets, and (when an
        autoscaler is wired) per-function replica targets.  No
        re-registration, no executable re-trace — pure control plane.
        Returns human-readable notes of what changed."""
        applied: List[str] = []
        by_op_id = {n.plan_op_id: n for n in dag.nodes.values()}
        for op_id, cfg in self.nodes.items():
            node = by_op_id.get(op_id)
            if node is None:
                continue
            if node.batching:
                changed = runtime.configure_batching(
                    dag.name, node.name, max_batch=cfg.max_batch,
                    batch_wait_ms=cfg.batch_wait_ms)
                if changed:
                    applied.append(
                        f"{node.name}: batcher max_batch={cfg.max_batch} "
                        f"window={cfg.batch_wait_ms:.2f}ms")
            if cfg.batch_buckets and node.batch_buckets and \
                    tuple(cfg.batch_buckets) != tuple(node.batch_buckets):
                runtime.set_node_buckets(dag.name, node.name,
                                         cfg.batch_buckets)
                applied.append(
                    f"{node.name}: buckets={list(cfg.batch_buckets)}")
            if autoscaler is not None and \
                    node.name in getattr(autoscaler, "functions", {}):
                autoscaler.set_target(node.name, cfg.target_replicas)
                applied.append(
                    f"{node.name}: replica target={cfg.target_replicas}")
        return applied

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {"slo_p99_s": self.slo_p99_s,
                "arrival_rate": self.arrival_rate,
                "notes": list(self.notes),
                "predicted": (self.predicted.summary()
                              if self.predicted else None),
                "nodes": {str(i): c.to_dict()
                          for i, c in sorted(self.nodes.items())}}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PlanConfig":
        return cls(slo_p99_s=d.get("slo_p99_s"),
                   arrival_rate=d.get("arrival_rate"),
                   notes=list(d.get("notes") or []),
                   nodes={int(i): NodeConfig.from_dict(c)
                          for i, c in (d.get("nodes") or {}).items()})

    def differs_runtime(self, other: "PlanConfig") -> bool:
        """Do the runtime-safe knobs differ (batcher/buckets/targets)?"""
        keys = set(self.nodes) | set(other.nodes)
        for k in keys:
            a, b = self.node(k), other.node(k)
            if (a.max_batch, a.batch_wait_ms, a.batch_buckets,
                    a.target_replicas) != \
                    (b.max_batch, b.batch_wait_ms, b.batch_buckets,
                     b.target_replicas):
                return True
        return False

    def needs_recompile(self, other: "PlanConfig") -> bool:
        """Do the compile-time knobs differ (lowering mode, placement,
        competitive replication)?  Those can't be hot-applied."""
        keys = set(self.nodes) | set(other.nodes)
        for k in keys:
            a, b = self.node(k), other.node(k)
            if (a.batched_lowering, a.placement, a.competitive_replicas) != \
                    (b.batched_lowering, b.placement, b.competitive_replicas):
                return True
        return False


# ---------------------------------------------------------------------------
# the search
# ---------------------------------------------------------------------------

def _candidate_batches(curve) -> Tuple[int, ...]:
    if curve is not None and curve.buckets:
        return tuple(sorted(curve.buckets))
    return _FALLBACK_BATCHES


def _window_for(b: int, lam: float, max_window_ms: float) -> float:
    """Batcher window that can actually accumulate b requests at rate lam,
    capped so a rate mis-estimate can't park requests forever."""
    if b <= 1:
        return 0.0
    return min(max_window_ms, 1e3 * (b - 1) / max(lam, 1e-9))


def _best_node_cfg(est: LatencyEstimator, op, wl: Workload, c: int,
                   max_window_ms: float, allow_batching: bool) \
        -> Tuple[NodeConfig, float]:
    """The (mode, batch) pair minimizing this node's modeled per-request
    p99 at ``c`` replicas.  Returns (config, node_p99)."""
    curve = est.profile.curve(op.op_id)
    lam = wl.arrival_rate
    best: Optional[Tuple[float, NodeConfig]] = None
    # per-row mode (batch of 1, no window)
    cands: List[NodeConfig] = [NodeConfig(
        max_batch=1, batch_buckets=(1,), batch_wait_ms=0.0,
        batched_lowering=False, target_replicas=c)]
    if allow_batching:
        for b in _candidate_batches(curve):
            cands.append(NodeConfig(
                max_batch=b,
                batch_buckets=tuple(x for x in _FALLBACK_BATCHES + (32, 64)
                                    if x <= b) or (b,),
                batch_wait_ms=_window_for(b, lam, max_window_ms),
                batched_lowering=b > 1 or curve is None
                or curve.per_row_s is None,
                target_replicas=c))
    for cfg in cands:
        ne = est.node_estimate(op.op_id, cfg, wl, curve=curve)
        # saturated points carry a finite utilization-ordered penalty, so
        # when nothing is feasible at this c the highest-throughput shape
        # (largest effective batch) still wins — the ascent fixes c next
        score = ne.p99_s
        if best is None or score < best[0]:
            best = (score, cfg)
    assert best is not None
    return best[1], best[0]


def propose(plan: PhysicalPlan, slo_p99: float, arrival_rate: float, *,
            profile: Optional[FlowProfile] = None, sample=None,
            net=None, kvs=None, request_rows: int = 1,
            max_replicas: int = 8, max_window_ms: float = 10.0,
            cv_competitive: float = 0.5,
            profile_runs: int = 2) -> PlanConfig:
    """SLO-aware configuration search (see module docstring).  ``profile``
    is an offline/refreshed :class:`FlowProfile`; when omitted, ``sample``
    is profiled on the spot.  ``slo_p99`` in seconds."""
    if profile is None:
        if sample is None:
            raise ValueError("propose() needs a FlowProfile or a sample "
                             "table to profile")
        profile = profile_plan(plan, sample, runs=profile_runs, kvs=kvs)
    est = LatencyEstimator(profile, net=net)
    wl = Workload(arrival_rate=arrival_rate, request_rows=request_rows)
    cfg = PlanConfig(nodes={}, slo_p99_s=slo_p99, arrival_rate=arrival_rate)

    # 1) per-node best (mode, batch) at one replica
    for o in plan.ops:
        if o.wait_any:
            cfg.nodes[o.op_id] = NodeConfig(target_replicas=1)
            continue
        allow_batching = bool(o.batching or o.batchable)
        node_cfg, _ = _best_node_cfg(est, o, wl, 1, max_window_ms,
                                     allow_batching)
        node_cfg.placement = o.placement
        cfg.nodes[o.op_id] = node_cfg
    pred = est.estimate(plan, cfg, wl)

    # 2) greedy replica ascent along the critical path
    total_added = 0
    budget = max_replicas * max(1, len(plan.ops))
    while not pred.meets(slo_p99) and total_added < budget:
        best_gain, best_choice = 0.0, None
        path = pred.critical_path or [o.op_id for o in plan.ops]
        for op_id in path:
            o = plan.op(op_id)
            if o.wait_any:
                continue
            cur = cfg.nodes.get(op_id)
            if cur is None or cur.target_replicas >= max_replicas:
                continue
            c = cur.target_replicas + 1
            trial_cfg, _ = _best_node_cfg(
                est, o, wl, c, max_window_ms,
                bool(o.batching or o.batchable))
            trial_cfg.placement = cur.placement
            trial = PlanConfig(nodes=dict(cfg.nodes))
            trial.nodes[op_id] = trial_cfg
            t_pred = est.estimate(plan, trial, wl)
            gain = pred.p99_s - t_pred.p99_s
            if gain > best_gain:
                best_gain, best_choice = gain, (op_id, trial_cfg, t_pred)
        if best_choice is None:
            break
        op_id, trial_cfg, pred = best_choice
        cfg.nodes[op_id] = trial_cfg
        total_added += 1
        cfg.notes.append(f"%{op_id}: +replica -> "
                         f"{trial_cfg.target_replicas} "
                         f"(p99 {pred.p99_s*1e3:.2f}ms)")

    # 3) competitive replication for tail-dominated critical nodes
    if not pred.meets(slo_p99):
        for op_id in (pred.critical_path or []):
            curve = profile.curve(op_id)
            o = plan.op(op_id)
            if o.wait_any or curve is None:
                continue
            cur = cfg.nodes[op_id]
            if curve.cv() > cv_competitive and \
                    cur.competitive_replicas < 2:
                cur.competitive_replicas = 3
                cfg.notes.append(f"%{op_id}: competitive x3 "
                                 f"(cv={curve.cv():.2f})")
        pred = est.estimate(plan, cfg, wl)

    cfg.predicted = pred
    cfg.notes.append(
        f"predicted p99 {pred.p99_s*1e3:.2f}ms vs SLO {slo_p99*1e3:.2f}ms"
        f" at {arrival_rate:.0f} req/s"
        + ("" if pred.meets(slo_p99) else " (NOT met"
           + ("" if pred.feasible else ", saturated") + ")"))
    return cfg
