"""Online SLO controller: the *replan* step of the
measure -> model -> plan -> replan loop.

``SLOController`` periodically (or on explicit ``tick()``, which is what
tests drive) closes the loop over a LIVE deployment:

1. **measure** — snapshot ``Runtime.metrics`` (consistent under the
   metrics lock), derive the current arrival rate from request
   timestamps, and fold every lowered chain's live ``ChainProfile``
   (measured per-row / per-bucket EWMAs) back into the offline
   :class:`~repro.profiling.profiler.FlowProfile` curves;
2. **model + plan** — re-run ``optimizer.propose`` at the measured rate;
3. **replan** — hot-apply the *runtime-safe* deltas (batcher window and
   max-batch, lowered-op padding buckets, autoscaler replica targets)
   through ``PlanConfig.apply_runtime`` — no flow re-registration, no
   executable re-trace; when the proposal needs compile-time changes
   (lowering mode, placement, competitive topology) AND the deployment
   misses the SLO — a missed latency estimate OR a rising error rate
   (failures must not read as "fast") — escalate: record a ``replan``
   event and invoke ``on_replan``.  When a ``replan_sample`` is
   provided, ``on_replan`` defaults to a
   :class:`~repro.profiling.replan.BlueGreenReplanner` over the same
   deployment: compile the green plan off the hot path, pre-warm its
   executables through the shared cache, canary-verify, atomically swap
   generations, and confirm the post-swap SLO on the next tick
   (``post_replan_confirm`` in the event detail).  Without a sample the
   escalation only records the event — a swap that can be neither
   warmed nor verified is not taken by default.

The controller never blocks the serving path: every step is control
plane, reading locked snapshots and mutating batcher/bucket/target knobs
that the hot path reads per call.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.obs import keys as okeys
from repro.obs.attribution import attribute
from repro.obs.clock import now as _mono
from repro.profiling.estimator import FaultStats, LatencyEstimator, Workload
from repro.profiling.optimizer import NodeConfig, PlanConfig, propose
from repro.profiling.profiler import FlowProfile, refresh_from_plan


@dataclasses.dataclass
class ControllerEvent:
    kind: str                    # "idle" | "steady" | "apply" | "replan"
    t: float
    arrival_rate: float
    detail: Dict[str, Any] = dataclasses.field(default_factory=dict)


class SLOController:
    """Watches one deployed flow and keeps its configuration matched to
    the measured traffic and the latency SLO."""

    def __init__(self, runtime, deployed, slo_p99_s: float, *,
                 profile: FlowProfile,
                 autoscaler=None,
                 interval_s: float = 0.5,
                 window_s: float = 5.0,
                 min_rate: float = 0.5,
                 max_replicas: int = 8,
                 max_window_ms: float = 10.0,
                 max_error_rate: float = 0.02,
                 replan_sample=None,
                 replan_cooldown_s: float = 30.0,
                 on_replan: Optional[Callable[[PlanConfig], Any]] = None):
        self.runtime = runtime
        self.deployed = deployed
        self.slo_p99_s = slo_p99_s
        self.profile = profile
        self.autoscaler = autoscaler
        self.interval_s = interval_s
        self.window_s = window_s
        self.min_rate = min_rate
        self.max_replicas = max_replicas
        self.max_window_ms = max_window_ms
        #: error fraction over the window above which the deployment
        #: counts as missing its SLO even if the (success-only) latency
        #: estimate looks fine
        self.max_error_rate = max_error_rate
        #: representative request table handed to the default replanner
        #: for executable warming + canary verification
        self.replan_sample = replan_sample
        #: after a FAILED replan (canary mismatch, compile error), wait
        #: this long before attempting another — each attempt costs a
        #: full compile + warm + canary round on the controller thread,
        #: and a persistent failure would otherwise re-run it every tick
        self.replan_cooldown_s = replan_cooldown_s
        self.on_replan = on_replan
        self.applied: Optional[PlanConfig] = None
        self.events: List[ControllerEvent] = []
        self._replanner = None          # lazily built default on_replan
        self._confirm_next = False      # a replan swapped; judge next tick
        self._last_handler = None       # who performed the last swap
                                        # (rollback target on failed confirm)
        self._next_replan_t = 0.0       # failure cooldown gate
        self._stop = False
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "SLOController":
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="slo-controller")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop = True

    def _loop(self) -> None:
        while not self._stop:
            try:
                self.tick()
            except Exception:       # the control loop must never die
                pass
            time.sleep(self.interval_s)

    # -- measurement ---------------------------------------------------------
    def arrival_rate(self,
                     snapshot: Optional[Dict[str, List[float]]] = None) \
            -> float:
        """Requests/s over the recent window, from the runtime's request
        timestamps for this DAG."""
        snap = snapshot if snapshot is not None \
            else self.runtime.metrics_snapshot()
        ts = snap.get(okeys.dag(self.deployed.dag.name, "request_t"), [])
        if len(ts) < 2:
            return 0.0
        # window against NOW (same clock call_dag stamps), not the newest
        # request — anchoring on ts[-1] would re-measure the last burst's
        # rate forever after traffic stops, pinning stale replica targets
        now = _mono()
        recent = [t for t in ts if t >= now - self.window_s]
        if len(recent) < 2:
            return 0.0
        span = recent[-1] - recent[0]
        if span <= 0:
            return 0.0
        return (len(recent) - 1) / span

    def error_rate(self,
                   snapshot: Optional[Dict[str, List[float]]] = None) \
            -> float:
        """Failed fraction of this DAG's requests completing in the
        recent window (error completions over all completions)."""
        snap = snapshot if snapshot is not None \
            else self.runtime.metrics_snapshot()
        name = self.deployed.dag.name
        now = _mono()
        lo = now - self.window_s
        errs = sum(1 for t in snap.get(okeys.dag(name, "error_t"), [])
                   if t >= lo)
        if errs == 0:
            return 0.0
        # successes carry no completion timestamp series; approximate the
        # window's total with arrivals (completions lag arrivals by one
        # latency — negligible at controller timescales).  An error burst
        # whose arrivals already left the window still reads as 100%.
        arrivals = sum(
            1 for t in snap.get(okeys.dag(name, "request_t"), [])
            if t >= lo)
        return errs / max(1, errs, arrivals)

    #: retries outnumbering successful completions by this factor over
    #: the window is a retry storm: recovery work has become the load
    RETRY_STORM_FACTOR = 3.0

    def fault_rate(self,
                   snapshot: Optional[Dict[str, List[float]]] = None) \
            -> Dict[str, float]:
        """Fault-tolerance activity over the recent window, in events per
        second: executor crashes and wedges (fleet-wide — a dead replica
        degrades every DAG sharing the pool), plus this DAG's retries and
        hedges.  Kept SEPARATE from :meth:`error_rate`: a recovered fault
        is invisible to callers and must not read as a request failure.
        ``storm`` is True when retries outnumber completions by
        :data:`RETRY_STORM_FACTOR` — at that point recovery work IS the
        load, and the deployment counts as missing its SLO."""
        snap = snapshot if snapshot is not None \
            else self.runtime.metrics_snapshot()
        name = self.deployed.dag.name
        lo = _mono() - self.window_s

        def count(key: str) -> int:
            return sum(1 for t in snap.get(key, []) if t >= lo)

        retries = count(okeys.dag(name, "retry_t"))
        # successful completions carry a latency sample, not a timestamp;
        # window-total approximated by arrivals, as in error_rate
        completions = count(okeys.dag(name, "request_t"))
        w = max(self.window_s, 1e-9)
        return {"crash_rate": count(okeys.fault("crash")) / w,
                "wedge_rate": count(okeys.fault("wedge")) / w,
                "requeue_rate": count(okeys.FAULT_REQUEUED) / w,
                "retry_rate": retries / w,
                "hedge_rate": count(okeys.dag(name, "hedge_t")) / w,
                "storm": float(
                    retries > self.RETRY_STORM_FACTOR
                    * max(1, completions))}

    def protection_rates(self,
                         snapshot: Optional[Dict[str, List[float]]] = None) \
            -> Dict[str, float]:
        """Overload-protection activity over the recent window, in events
        per second: requests shed at the admission gate, expired before
        dispatch, and admitted degraded.  These series are SEPARATE from
        ``error_t`` by design — a deployment shedding by policy is
        protecting itself, not failing, and must not read as an
        error-rate SLO miss."""
        snap = snapshot if snapshot is not None \
            else self.runtime.metrics_snapshot()
        name = self.deployed.dag.name
        lo = _mono() - self.window_s

        def count(key: str) -> int:
            return sum(1 for t in snap.get(key, []) if t >= lo)

        degraded = sum(
            count(k) for k in snap
            if k.startswith(f"admission/{name}/")
            and k.endswith("/degraded_t"))
        w = max(self.window_s, 1e-9)
        return {"shed_rate": count(okeys.dag(name, "shed_t")) / w,
                "expired_rate": count(okeys.dag(name, "expired_t")) / w,
                "degraded_rate": degraded / w}

    def refresh_profile(self) -> bool:
        """Fold live ChainProfile measurements into the curves."""
        return refresh_from_plan(self.profile, self.deployed.plan)

    def _default_replanner(self):
        """The default ``on_replan``: a BlueGreenReplanner over this
        deployment (built lazily; needs the logical flow and the recorded
        compile flags to produce an op-id-stable recompile).  Without a
        ``replan_sample`` there is NO default: a sample is what makes the
        swap warm (zero post-swap traces) and canary-verified — silently
        swapping a cold, unverified plan under a live SLO miss would be
        worse than recording the event, the pre-PR-5 behavior."""
        if self._replanner is None:
            from repro.profiling.replan import BlueGreenReplanner
            if getattr(self.deployed, "flow", None) is None \
                    or self.replan_sample is None:
                return None
            self._replanner = BlueGreenReplanner(
                self.runtime, self.deployed, sample=self.replan_sample,
                autoscaler=self.autoscaler)
        return self._replanner

    # -- the loop body -------------------------------------------------------
    def tick(self) -> ControllerEvent:
        now = _mono()
        # prefix-filtered snapshot: the controller only reads this DAG's
        # series plus the fleet-wide fault series, so the metrics-lock
        # hold no longer scales with every OTHER deployment's history
        name = self.deployed.dag.name
        snap = self.runtime.metrics_snapshot(
            prefix=(f"dag/{name}/", "faults/", f"admission/{name}/"))
        rate = self.arrival_rate(snap)
        if rate < self.min_rate:
            ev = ControllerEvent("idle", now, rate)
            self.events.append(ev)
            return ev
        self.refresh_profile()
        proposal = propose(self.deployed.plan, self.slo_p99_s, rate,
                           profile=self.profile, net=self.runtime.net,
                           max_replicas=self.max_replicas,
                           max_window_ms=self.max_window_ms)
        detail: Dict[str, Any] = {
            "predicted_p99_ms": (proposal.predicted.p99_s * 1e3
                                 if proposal.predicted else None)}

        kind = "steady"
        if self.applied is None or proposal.differs_runtime(self.applied):
            notes = proposal.apply_runtime(self.runtime, self.deployed.dag,
                                           autoscaler=self.autoscaler)
            if notes:
                kind = "apply"
                detail["applied"] = notes

        # does the deployment as it NOW stands meet the SLO?  That is the
        # proposal's runtime-safe knobs (just applied above) with the
        # compile-time facts — lowering mode, competitive topology,
        # placement — read back from the LIVE plan: judging against the
        # pre-apply config would escalate a replan whose safe deltas
        # already fixed the miss, and trusting the proposal's unapplied
        # compile-time knobs would mask a persistent miss forever
        current = self._live_config(proposal)
        # fault-tolerance activity feeds the estimator: crashes and
        # hedged stragglers that RECOVERED don't show up in error_t, but
        # disturbed requests re-pay the path — the fault-aware estimator
        # inflates the predicted p99 by the measured disturbed fraction
        # instead of judging the SLO against a clean-path fiction
        fault = self.fault_rate(snap)
        fstats = FaultStats(
            crash_rate=fault["crash_rate"],
            wedge_rate=fault["wedge_rate"],
            retry_rate=fault["retry_rate"],
            requeue_rate=fault["requeue_rate"],
            detection_s=getattr(self.runtime, "detector_interval_s", 0.0))
        cur_pred = LatencyEstimator(self.profile, net=self.runtime.net,
                                    fault=fstats) \
            .estimate(self.deployed.plan, current,
                      Workload(arrival_rate=rate))
        detail["current_p99_ms"] = cur_pred.p99_s * 1e3
        detail["fault_inflation"] = fstats.disturbed_fraction(rate)
        # a rising error rate is an SLO miss: the latency series only
        # records successes, so under failures the measured (and modeled)
        # p99 improves exactly when the system degrades
        err_rate = self.error_rate(snap)
        detail["error_rate"] = err_rate
        # a retry storm (recovery work exceeding completions) means the
        # deployment is burning capacity re-executing — an SLO miss even
        # while callers still get answers
        detail["fault"] = fault
        slo_ok = cur_pred.meets(self.slo_p99_s) \
            and err_rate <= self.max_error_rate \
            and not fault["storm"]
        detail["slo_ok"] = slo_ok
        # overload protection activity: shed/expired/degraded decisions
        # ride their own metric series, so the controller can tell
        # "overloaded and protecting itself" (admission gate active,
        # surviving traffic healthy) from "missing SLO" (it is not)
        prot = self.protection_rates(snap)
        detail["protection"] = prot
        detail["protecting"] = any(v > 0 for v in prot.values())
        # SLO-miss attribution from the tracer's kept traces: the
        # per-node queue/service/transfer/retry/hedge breakdown, with the
        # dominant contributor named — the "why" next to every miss
        tracer = getattr(self.runtime, "tracer", None)
        if tracer is not None and tracer.enabled:
            kept = tracer.kept(name)
            if kept:
                detail["attribution"] = attribute(
                    kept, slo_only=True).to_dict()
        adm = getattr(self.runtime, "admission_for", lambda _n: None)(
            self.deployed.dag.name)
        if adm is not None:
            # keep the gate's model pointed at the LIVE deployment: same
            # plan, same measured curves, same applied config the
            # controller just judged
            adm.update(plan=self.deployed.plan, profile=self.profile,
                       config=current)
            detail["admission"] = adm.snapshot()
        if self._confirm_next:
            # the previous tick swapped generations: judge the post-swap
            # deployment against the SLO and say so
            self._confirm_next = False
            confirm: Dict[str, Any] = {
                "p99_ms": cur_pred.p99_s * 1e3, "slo_ok": slo_ok}
            tracer = getattr(self.runtime, "tracer", None)
            if tracer is not None and getattr(tracer, "enabled", False):
                ce = getattr(tracer, "control_event", None)
                if ce is not None:
                    # the confirm verdict closes the swap lifecycle on
                    # the control track (prepare/warm/canary/swap/confirm)
                    ce(f"replan@{self.deployed.dag.name}", phase="confirm",
                       ok=slo_ok, p99_ms=cur_pred.p99_s * 1e3)
            if not slo_ok:
                # green failed its confirm: roll back to blue
                # automatically, and cool down so the very next tick does
                # not re-compile the same failing green
                sb = getattr(self._last_handler, "swap_back", None)
                rb = sb(f"post_replan_confirm failed: p99 "
                        f"{cur_pred.p99_s * 1e3:.1f}ms, err {err_rate:.3f}") \
                    if sb is not None else None
                if rb:
                    confirm["rollback"] = rb
                    kind = "replan"
                    detail["rolled_back"] = True
                    self._next_replan_t = now + self.replan_cooldown_s
            detail["post_replan_confirm"] = confirm
        if not slo_ok \
                and self._needs_recompile(proposal) \
                and proposal.predicted is not None \
                and proposal.predicted.p99_s < cur_pred.p99_s:
            # safe deltas alone don't reach the SLO and the proposal wants
            # compile-time changes the live plan can't express (lowering
            # mode / placement / competitive topology): escalate
            kind = "replan"
            detail["recompile"] = True
            if now < self._next_replan_t:
                # a recent attempt failed; don't burn a compile + warm +
                # canary round every tick on a fault that hasn't changed
                detail["replan_suppressed_s"] = self._next_replan_t - now
            else:
                handler = self.on_replan or self._default_replanner()
                if handler is not None:
                    result = handler(proposal)
                    report = getattr(result, "to_dict", None)
                    if report is not None:
                        detail["replan_report"] = report()
                    if getattr(result, "ok", False):
                        # green is live — confirm SLO on the next tick
                        self._confirm_next = True
                        self._last_handler = handler
                    elif hasattr(result, "ok"):
                        self._next_replan_t = now + self.replan_cooldown_s
        self.applied = proposal
        ev = ControllerEvent(kind, now, rate, detail)
        self.events.append(ev)
        return ev

    def _live_config(self, applied: Optional[PlanConfig]) -> PlanConfig:
        """The deployment as it actually is: the applied runtime-safe
        knobs (or defaults) with compile-time facts — lowering mode,
        competitive replication — read back from the live plan."""
        import dataclasses as _dc

        from repro.core.lowering import BatchedJittedFuse
        plan = self.deployed.plan
        # competitive topology is EXPANDED in a compiled plan: the factor
        # lives in the wait-any consumer's input count, not in .replicas
        # (CompetitivePass resets the replica ops' annotation to 0)
        competitive: Dict[int, int] = {}
        for o in plan.ops:
            if o.wait_any and len(o.inputs) >= 2:
                competitive[o.op_id] = len(o.inputs)
                for i in o.inputs:
                    competitive[i] = len(o.inputs)
        cfg = PlanConfig(nodes={}, slo_p99_s=self.slo_p99_s)
        for o in plan.ops:
            base = applied.nodes.get(o.op_id) if applied else None
            nc = _dc.replace(base) if base is not None else NodeConfig()
            nc.batched_lowering = isinstance(o.op, BatchedJittedFuse)
            nc.competitive_replicas = competitive.get(o.op_id, o.replicas)
            nc.placement = o.placement
            cfg.nodes[o.op_id] = nc
        return cfg

    def _needs_recompile(self, proposal: PlanConfig) -> bool:
        """Does the proposal want compile-time changes relative to the
        LIVE plan?  Compared against what the deployment actually is, not
        against earlier proposals: a batched-lowered op serves both
        per-row and vmapped execution through its adaptive router, so a
        ``batched_lowering`` flip only needs a recompile in the
        per-row-lowered -> batched direction."""
        from repro.core.lowering import BatchedJittedFuse, JittedFuse
        for o in self.deployed.plan.ops:
            cfg = proposal.nodes.get(o.op_id)
            if cfg is None:
                continue
            if o.wait_any:
                # this slot already IS a competitive wait-any consumer —
                # asking for competitive execution here is satisfied
                continue
            if cfg.placement is not None and cfg.placement != o.placement:
                return True
            if cfg.competitive_replicas >= 2 and o.replicas < 2:
                return True
            if cfg.batched_lowering and cfg.max_batch > 1 \
                    and isinstance(o.op, JittedFuse) \
                    and not isinstance(o.op, BatchedJittedFuse):
                return True
        return False
