"""SLO-driven profiling & adaptive replanning: the layer between the
compiler and the runtime that closes the measure -> model -> plan ->
replan loop.

* :mod:`repro.profiling.profiler` — offline batch-sweep profiler
  (``OpLatencyCurve`` / ``FlowProfile``), plus live-curve refresh from
  ``ChainProfile`` measurements;
* :mod:`repro.profiling.estimator` — M/M/c + critical-path DAG latency
  estimator (``LatencyEstimator``);
* :mod:`repro.profiling.optimizer` — SLO-aware configuration search
  (``propose`` -> ``PlanConfig``);
* :mod:`repro.profiling.controller` — online controller that snapshots
  runtime metrics and hot-applies safe config deltas (``SLOController``);
* :mod:`repro.profiling.replan` — zero-downtime blue/green replanning
  (``BlueGreenReplanner``): compile off the hot path, pre-warm
  executables, canary-verify, atomically swap generations.
"""
from repro.profiling.controller import ControllerEvent, SLOController
from repro.profiling.estimator import (LatencyEstimate, LatencyEstimator,
                                       Workload, erlang_c)
from repro.profiling.optimizer import NodeConfig, PlanConfig, propose
from repro.profiling.profiler import (BucketStats, FlowProfile,
                                      OpLatencyCurve, profile_flow_curves,
                                      profile_plan, refresh_from_plan)
from repro.profiling.replan import (BlueGreenReplanner, ReplanReport,
                                    warm_deployment)

__all__ = [
    "BlueGreenReplanner", "BucketStats", "ControllerEvent", "FlowProfile",
    "LatencyEstimate", "LatencyEstimator", "NodeConfig", "OpLatencyCurve",
    "PlanConfig", "ReplanReport", "SLOController", "Workload", "erlang_c",
    "profile_flow_curves", "profile_plan", "propose", "refresh_from_plan",
    "warm_deployment",
]
