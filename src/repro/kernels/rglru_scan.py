"""Pallas TPU RG-LRU linear-recurrence scan (RecurrentGemma hot-spot).

h_t = a_t * h_{t-1} + x_t, elementwise over the recurrent width R.
Grid: (B, nR, n_chunks); chunks sequential with the [Rb] hidden state in
VMEM scratch; within a chunk a fori_loop applies the diagonal recurrence.
(The training path uses ``lax.associative_scan``; this kernel is the
streaming form used for long sequences / decode-prefill.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _rglru_kernel(a_ref, x_ref, h0_ref, y_ref, h_ref, *, ct: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    def step(t, h):
        a = a_ref[0, t].astype(jnp.float32)        # [Rb]
        x = x_ref[0, t].astype(jnp.float32)
        h = a * h + x
        y_ref[0, t] = h.astype(y_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, ct, step, h_ref[...])


def rglru_scan(a, x, h0=None, *, chunk: int = 128, block_r: int = 512,
               interpret: bool = False):
    """a, x: [B, T, R] (decay in (0,1), gated input); h0: [B, R] or None.
    Returns h trajectory [B, T, R] (f32)."""
    B, T, R = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, R), jnp.float32)
    ct = min(chunk, T)
    br = min(block_r, R)
    assert T % ct == 0 and R % br == 0
    nc, nr = T // ct, R // br

    kernel = functools.partial(_rglru_kernel, ct=ct)
    y = pl.pallas_call(
        kernel,
        grid=(B, nr, nc),
        in_specs=[
            pl.BlockSpec((1, ct, br), lambda b, r, c: (b, c, r)),
            pl.BlockSpec((1, ct, br), lambda b, r, c: (b, c, r)),
            pl.BlockSpec((1, br), lambda b, r, c: (b, r)),
        ],
        out_specs=pl.BlockSpec((1, ct, br), lambda b, r, c: (b, c, r)),
        out_shape=jax.ShapeDtypeStruct((B, T, R), jnp.float32),
        scratch_shapes=[pltpu.VMEM((br,), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, x, h0)
    return y
