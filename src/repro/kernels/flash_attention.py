"""Pallas TPU flash attention (prefill hot-spot).

Grid: (batch*q_heads, nq, nk) — the kv dimension is sequential ("arbitrary")
so the online-softmax running stats live in VMEM scratch across kv steps.
Block shapes are MXU-aligned (q/k tiles multiples of 128 where the problem
allows).  GQA is handled in the kv index_map (q head -> kv head // group).

Validated in interpret mode against ``repro.kernels.ref.attention_ref``
(tests/test_kernels.py sweeps shapes/dtypes).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, softcap: float,
                  bq: int, bk: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)          # [bq, hd]
    k = k_ref[0].astype(jnp.float32)          # [bk, hd]
    v = v_ref[0].astype(jnp.float32)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale    # [bq, bk]
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
    p = jnp.exp(logits - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: [B, H, S, hd]; k, v: [B, K, S, hd] with H = K*G.  -> [B, H, S, hd]
    """
    B, H, S, hd = q.shape
    K = k.shape[1]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk

    qf = q.reshape(B * H, S, hd)
    kf = k.reshape(B * K, S, hd)
    vf = v.reshape(B * K, S, hd)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, nk=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, hd),
                         lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
            pl.BlockSpec((1, bk, hd),
                         lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd)
