"""Pallas TPU decode attention: ONE query token against a long KV cache.

This is the serving hot-spot at decode_32k / long_500k: memory-bound
streaming of the cache through VMEM.  Grid: (B, K, nS) with the kv/sequence
dimension sequential; online-softmax stats for the G query heads of each kv
head live in scratch.  Supports the ring-buffer cache layout (per-slot
positions, -1 = empty) used by the model zoo.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, kpos_ref, qpos_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, scale: float, window: int,
                   softcap: float, bs: int, ns: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # [G, hd]
    k = k_ref[0, 0].astype(jnp.float32)              # [bs, hd]
    v = v_ref[0, 0].astype(jnp.float32)
    kpos = kpos_ref[0, 0]                            # [bs]
    qpos = qpos_ref[0, 0]                            # scalar int32

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # [G, bs]
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    valid = (kpos >= 0) & (kpos <= qpos)
    if window > 0:
        valid &= (qpos - kpos) < window
    logits = jnp.where(valid[None, :], logits, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
    p = jnp.exp(logits - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(si == ns - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, k_positions, q_position, *,
                     window: int = 0, softcap: float = 0.0,
                     scale: Optional[float] = None, block_s: int = 512,
                     interpret: bool = False):
    """q: [B, H, hd]; k_cache/v_cache: [B, K, S, hd];
    k_positions: [B, S] int32 (−1 empty); q_position: [B] int32.
    Returns [B, H, hd]."""
    B, H, hd = q.shape
    K, S = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    bs = min(block_s, S)
    assert S % bs == 0
    ns = S // bs
    qg = q.reshape(B, K, G, hd)
    kpos = jnp.broadcast_to(k_positions[:, None], (B, K, S))
    qpos = jnp.broadcast_to(q_position[:, None], (B, K)).astype(jnp.int32)

    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               softcap=softcap, bs=bs, ns=ns)
    out = pl.pallas_call(
        kernel,
        grid=(B, K, ns),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, kh, si: (b, kh, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, kh, si: (b, kh, si, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, kh, si: (b, kh, si, 0)),
            pl.BlockSpec((1, 1, bs), lambda b, kh, si: (b, kh, si)),
            pl.BlockSpec((1, 1), lambda b, kh, si: (b, kh)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, kh, si: (b, kh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qg, k_cache, v_cache, kpos, qpos)
    return out.reshape(B, H, hd)
