"""Pallas TPU WKV6 chunked scan (RWKV-6 recurrence hot-spot).

The recurrence S <- diag(w_t) S + k_t v_t^T; y_t = r_t (S + u k_t v_t^T)
is sequential in t, so the grid is (B, H, n_chunks) with the chunk dimension
"arbitrary" (sequential) and the [hd, hd] matrix state in VMEM scratch across
chunk steps.  Inside a chunk, a fori_loop walks the timesteps — HBM traffic
is chunked (r/k/v/w tiles), the state never leaves VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_ref, *,
                ct: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[0].astype(jnp.float32)                   # [hd]

    def step(t, S):
        rt = r_ref[0, 0, t].astype(jnp.float32)        # [hd]
        kt = k_ref[0, 0, t].astype(jnp.float32)
        vt = v_ref[0, 0, t].astype(jnp.float32)
        wt = w_ref[0, 0, t].astype(jnp.float32)
        kv = kt[:, None] * vt[None, :]                 # [hd_k, hd_v]
        y = jnp.sum(rt[:, None] * (S + u[:, None] * kv), axis=0)
        y_ref[0, 0, t] = y.astype(y_ref.dtype)
        return wt[:, None] * S + kv

    s_ref[...] = jax.lax.fori_loop(0, ct, step, s_ref[...])


def wkv6(r, k, v, w, u, *, chunk: int = 64, interpret: bool = False):
    """r,k,v,w: [B, T, H, hd]; u: [H, hd].  Returns y [B, T, H, hd] (f32).

    w is the per-step decay in (0, 1); initial state is zero (fresh
    sequence), matching ``repro.models.rwkv6.wkv_scan``.
    """
    B, T, H, hd = r.shape
    ct = min(chunk, T)
    assert T % ct == 0
    nc = T // ct
    # layout [B, H, T, hd] so the chunk dim tiles cleanly
    perm = (0, 2, 1, 3)
    rt, kt, vt, wt = (x.transpose(perm) for x in (r, k, v, w))

    kernel = functools.partial(_wkv_kernel, ct=ct, nc=nc)
    y = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, ct, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, ct, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, ct, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, ct, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, hd), lambda b, h, c: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, ct, hd), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(rt, kt, vt, wt, u)
    return y.transpose(perm)
