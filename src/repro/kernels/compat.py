"""Pallas API compatibility across jax versions.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in
newer jax releases; the kernels are written against the new name.  Import
``CompilerParams`` from here so both work.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))

if CompilerParams is None:             # fail loudly at the call site
    def CompilerParams(*args, **kwargs):
        raise ImportError(
            "this jax version exposes neither pallas.tpu.CompilerParams "
            "nor TPUCompilerParams; update repro.kernels.compat for it")
