"""Jitted public wrappers for the Pallas kernels + the kernel registry.

On CPU (this container) the kernels run in ``interpret=True`` mode — the
kernel body executes in Python for correctness validation; on TPU the same
``pl.pallas_call`` lowers to Mosaic.  ``interpret=None`` auto-detects — the
detection is resolved ONCE per process (``_default_interpret``) *before*
the jitted call, so the jit cache key is always a concrete bool (``None``
vs ``True`` would otherwise compile two identical executables) and the
backend probe is never paid per dispatch.

The **kernel registry** is what lets the compiler place these kernels into
lowered chains (``PlaceKernelsPass``):

* ``KERNEL_REGISTRY`` describes each kernel: the jitted Pallas wrapper,
  its pure-jnp oracle from :mod:`repro.kernels.ref`, and which keyword
  params are *semantic* (change the math — the oracle takes them too) vs
  *tile* (block sizes — Pallas-only scheduling knobs).
* ``kernel_step(name, **params)`` builds a dataflow ``Map`` step function:
  ``jax.Array``-annotated, computing via the *oracle* (so un-placed plans
  and ``execute_local`` stay correct), tagged with a :class:`KernelCall`.
  Steps are memoized per ``(kernel, params)`` so recompiles of the same
  flow share function identity — ``chain_signature`` keys the executable
  cache and router state on the function objects.
* Every step carries its Pallas twin (``__kernel_placed__``): the same
  signature/annotations but computing via the Pallas wrapper, wrapped in
  ``jax.custom_batching.custom_vmap`` so that when a lowered chain vmaps
  the step over a row batch, the batch dim maps onto the kernel's native
  leading ``B`` dimension — ONE Pallas dispatch per batch, not a generic
  per-row batching rule.
* ``register_pattern(fn, kernel, **params)`` pattern-matches an existing
  user function object to a kernel, for code that cannot be annotated.

Distinct params produce distinct step objects, so two chains differing
only in block sizes get separate executable-cache entries and separate
``ChainProfile`` routing state — per-variant, as profiling requires.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.wkv6 import wkv6 as _wkv6
from repro.kernels.rglru_scan import rglru_scan as _rglru


# ---------------------------------------------------------------------------
# interpret auto-detection: resolved once, outside the jitted call
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    return _default_interpret() if interpret is None else bool(interpret)


_flash_jit = jax.jit(_flash, static_argnames=(
    "causal", "window", "softcap", "scale", "block_q", "block_k",
    "interpret"))
_decode_jit = jax.jit(_decode, static_argnames=(
    "window", "softcap", "scale", "block_s", "interpret"))
_wkv6_jit = jax.jit(_wkv6, static_argnames=("chunk", "interpret"))
_rglru_jit = jax.jit(_rglru, static_argnames=("chunk", "block_r",
                                              "interpret"))


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale=None, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    return _flash_jit(q, k, v, causal=causal, window=window,
                      softcap=softcap, scale=scale, block_q=block_q,
                      block_k=block_k,
                      interpret=_resolve_interpret(interpret))


def decode_attention(q, k_cache, v_cache, k_positions, q_position, *,
                     window: int = 0, softcap: float = 0.0, scale=None,
                     block_s: int = 512, interpret: Optional[bool] = None):
    return _decode_jit(q, k_cache, v_cache, k_positions, q_position,
                       window=window, softcap=softcap, scale=scale,
                       block_s=block_s,
                       interpret=_resolve_interpret(interpret))


def wkv6(r, k, v, w, u, *, chunk: int = 64,
         interpret: Optional[bool] = None):
    return _wkv6_jit(r, k, v, w, u, chunk=chunk,
                     interpret=_resolve_interpret(interpret))


def rglru_scan(a, x, h0=None, *, chunk: int = 128, block_r: int = 512,
               interpret: Optional[bool] = None):
    return _rglru_jit(a, x, h0, chunk=chunk, block_r=block_r,
                      interpret=_resolve_interpret(interpret))


# ---------------------------------------------------------------------------
# kernel registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelCall:
    """Identity of one kernel placement: kernel name + sorted params.
    Hashable, so it keys the step/placement memo tables — which is what
    makes step function objects (and therefore ``chain_signature`` cache
    keys) stable across recompiles of the same flow."""
    kernel: str
    params: Tuple[Tuple[str, Any], ...] = ()

    def kwargs(self) -> Dict[str, Any]:
        return dict(self.params)

    def __repr__(self):
        ps = ", ".join(f"{k}={v}" for k, v in self.params)
        return f"{self.kernel}({ps})"


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One placeable kernel: the Pallas entry point, its jnp oracle, the
    step's column names, and the param split (semantic params reach the
    oracle too; tile params are Pallas-only block sizes)."""
    name: str
    fn: Callable                 # jitted Pallas wrapper, leading batch dim
    ref: Callable                # pure-jnp oracle, leading batch dim
    args: Tuple[str, ...]        # step argument (column) order
    sem_params: Tuple[str, ...] = ()
    tile_params: Tuple[str, ...] = ()

    def split(self, params: Dict[str, Any]):
        unknown = set(params) - set(self.sem_params) - set(self.tile_params)
        if unknown:
            raise ValueError(f"{self.name}: unknown params {sorted(unknown)}")
        sem = {k: v for k, v in params.items() if k in self.sem_params}
        return sem, dict(params)

    def check_tiles(self, shapes, params) -> "list":
        """Static tile validation for the plan verifier (CF103): restate
        this kernel's call-time divisibility asserts against inferred
        operand shapes, so a bad block size fails the compile instead of
        the first dispatch.  ``shapes`` maps operand column -> shape
        tuple (``None`` skips the shape-dependent rules, leaving only
        positivity); ``params`` is the placement's param dict or
        ``KernelCall.params`` pairs.  Returns problem strings; empty
        means the placement tiles cleanly."""
        p = dict(params)
        problems = []
        for pname, default, arg, dim_idx in _TILE_RULES.get(self.name, ()):
            val = p.get(pname, default)
            if not isinstance(val, int) or isinstance(val, bool) \
                    or val <= 0:
                problems.append(f"{pname}={val!r} must be a positive int")
                continue
            if not shapes or arg not in shapes:
                continue
            shape = tuple(shapes[arg])
            if len(shape) < -dim_idx:
                problems.append(f"{arg} has rank {len(shape)}, the "
                                f"{pname} rule tiles dim {dim_idx}")
                continue
            dim = shape[dim_idx]
            eff = min(val, dim)
            if eff <= 0 or dim % eff:
                problems.append(
                    f"{arg}.shape[{dim_idx}]={dim} is not divisible by "
                    f"effective {pname}=min({val},{dim})={eff}")
        return problems


#: per kernel: (tile param, default, operand column, dim index) — the
#: divisibility rules the Pallas entry points assert, restated for
#: static checking.  Dim indexes are NEGATIVE so the same rule covers
#: both full batched operands ([B,...]) and the verifier's row-level
#: specs (batch dim stripped).
_TILE_RULES: Dict[str, Tuple[Tuple[str, int, str, int], ...]] = {
    "flash_attention": (("block_q", 128, "q", -2),
                        ("block_k", 128, "q", -2)),
    "decode_attention": (("block_s", 512, "k_cache", -2),),
    "wkv6": (("chunk", 64, "r", -3),),
    "rglru_scan": (("chunk", 128, "a", -2),
                   ("block_r", 512, "a", -1)),
}


KERNEL_REGISTRY: Dict[str, KernelSpec] = {
    "flash_attention": KernelSpec(
        name="flash_attention", fn=flash_attention, ref=ref.attention_ref,
        args=("q", "k", "v"),
        sem_params=("causal", "window", "softcap", "scale"),
        tile_params=("block_q", "block_k")),
    "decode_attention": KernelSpec(
        name="decode_attention", fn=decode_attention,
        ref=ref.decode_attention_ref,
        args=("q", "k_cache", "v_cache", "k_positions", "q_position"),
        sem_params=("window", "softcap", "scale"),
        tile_params=("block_s",)),
    "wkv6": KernelSpec(
        name="wkv6", fn=wkv6, ref=ref.wkv6_ref,
        args=("r", "k", "v", "w", "u"),
        tile_params=("chunk",)),
    "rglru_scan": KernelSpec(
        name="rglru_scan", fn=rglru_scan, ref=ref.rglru_scan_ref,
        args=("a", "x"),
        tile_params=("chunk", "block_r")),
}

#: user fn object -> KernelCall, for code that can't carry the step tag
KERNEL_PATTERNS: Dict[Callable, KernelCall] = {}


def register_pattern(fn: Callable, kernel: str, **params) -> Callable:
    """Pattern-match ``fn`` (an existing map function computing what
    ``kernel`` computes) to the kernel, so ``PlaceKernelsPass`` swaps it.
    Returns ``fn`` for decorator use."""
    call = _call(kernel, params)
    KERNEL_PATTERNS[fn] = call
    return fn


def _call(kernel: str, params: Dict[str, Any]) -> KernelCall:
    if kernel not in KERNEL_REGISTRY:
        raise ValueError(f"unknown kernel {kernel!r}; have "
                         f"{sorted(KERNEL_REGISTRY)}")
    KERNEL_REGISTRY[kernel].split(params)   # validate names
    return KernelCall(kernel, tuple(sorted(params.items())))


def match_kernel(fn) -> Optional[KernelCall]:
    """The ``PlaceKernelsPass`` probe: the step tag, else the pattern
    table."""
    call = getattr(fn, "__kernel__", None)
    if call is not None:
        return call
    return KERNEL_PATTERNS.get(fn)


def kernel_call_of(fn) -> Optional[KernelCall]:
    """The static verifier's probe (same resolution as ``match_kernel``):
    the ``KernelCall`` behind a step function, whether it is the oracle
    step or its placed Pallas twin."""
    return match_kernel(fn)


# -- step construction -------------------------------------------------------

def _named_fn(fname: str, argnames: Tuple[str, ...],
              inner: Callable) -> Callable:
    """A function with explicit positional args (``fn_signature`` reads
    ``__code__``) and jax.Array annotations, delegating to ``inner``."""
    src = (f"def {fname}({', '.join(argnames)}):\n"
           f"    return _inner({', '.join(argnames)})")
    ns: Dict[str, Any] = {"_inner": inner}
    exec(src, ns)                                       # noqa: S102
    f = ns[fname]
    f.__annotations__ = {a: jax.Array for a in argnames}
    f.__annotations__["return"] = jax.Array
    return f


def _broadcast_unbatched(axis_size, cols, in_batched):
    return [c if b else jnp.broadcast_to(c[None], (axis_size,) + c.shape)
            for c, b in zip(cols, in_batched)]


def _make_placed(spec: KernelSpec, call: KernelCall,
                 bound: Tuple[Tuple[str, Any], ...]) -> Callable:
    """The Pallas twin of a step: per-row it adds the batch dim and calls
    the kernel with ``B=1``; under ``jax.vmap`` (the batched-lowered
    chain) a ``custom_vmap`` rule maps the row axis straight onto the
    kernel's native batch dimension — one dispatch for the whole batch."""
    _, kw = spec.split(call.kwargs())
    bound_vals = [v for _, v in bound]

    def batched(*cols):
        return spec.fn(*cols, *bound_vals, **kw)

    @jax.custom_batching.custom_vmap
    def per_row(*cols):
        return batched(*[c[None] for c in cols])[0]

    @per_row.def_vmap
    def _rule(axis_size, in_batched, *cols):        # noqa: ANN001
        cols = _broadcast_unbatched(axis_size, cols, in_batched)
        return batched(*cols), True

    fn = _named_fn(f"pallas_{spec.name}", spec.args, per_row)
    fn.__kernel__ = call
    fn.__kernel_params__ = call.kwargs()
    return fn


def _make_step(spec: KernelSpec, call: KernelCall,
               bound: Tuple[Tuple[str, Any], ...]) -> Callable:
    sem, _ = spec.split(call.kwargs())
    bound_vals = [v for _, v in bound]

    def via_ref(*cols):
        out = spec.ref(*[c[None] for c in cols], *bound_vals, **sem)
        return out[0]

    fn = _named_fn(spec.name, spec.args, via_ref)
    fn.__kernel__ = call
    fn.__kernel_placed__ = _make_placed(spec, call, bound)
    return fn


#: (KernelCall, bound ids) -> step fn — function-object stability across
#: recompiles is what keeps executable-cache keys and router state shared
_STEPS: Dict[Tuple[KernelCall, Tuple[Tuple[str, int], ...]], Callable] = {}
_PLACED: Dict[KernelCall, Callable] = {}


def kernel_step(kernel: str, *, bound: Optional[Dict[str, Any]] = None,
                **params) -> Callable:
    """A dataflow map step for ``kernel``: jax.Array-annotated, oracle
    semantics, tagged for placement.  ``bound`` holds trailing kernel
    arguments closed over as constants rather than consumed as columns
    (e.g. ``wkv6``'s shared ``u`` bonus matrix, which is per-model, not
    per-row).  Memoized per ``(kernel, params, bound identities)``."""
    call = _call(kernel, params)
    bound_t = tuple(sorted((bound or {}).items()))
    key = (call, tuple((k, id(v)) for k, v in bound_t))
    fn = _STEPS.get(key)
    if fn is None:
        spec = KERNEL_REGISTRY[kernel]
        n_bound = len(bound_t)
        if n_bound:
            spec = dataclasses.replace(spec,
                                       args=spec.args[:len(spec.args)
                                                      - n_bound])
        fn = _STEPS[key] = _make_step(spec, call, bound_t)
    return fn


def placed_fn(call: KernelCall) -> Callable:
    """The memoized Pallas twin for a *pattern-matched* call (steps built
    by ``kernel_step`` already carry theirs on ``__kernel_placed__``)."""
    fn = _PLACED.get(call)
    if fn is None:
        fn = _PLACED[call] = _make_placed(KERNEL_REGISTRY[call.kernel],
                                          call, ())
    return fn


def placed_twin(fn: Callable) -> Optional[Callable]:
    """Resolve the Pallas replacement for a map function, if any: the
    step's own twin, else the registry twin of its matched call."""
    twin = getattr(fn, "__kernel_placed__", None)
    if twin is not None:
        return twin
    call = match_kernel(fn)
    if call is not None:
        return placed_fn(call)
    return None
