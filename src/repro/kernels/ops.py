"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) the kernels run in ``interpret=True`` mode — the
kernel body executes in Python for correctness validation; on TPU the same
``pl.pallas_call`` lowers to Mosaic.  ``interpret=None`` auto-detects.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.wkv6 import wkv6 as _wkv6
from repro.kernels.rglru_scan import rglru_scan as _rglru


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "block_q", "block_k",
    "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale=None, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  scale=scale, block_q=block_q, block_k=block_k,
                  interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=(
    "window", "softcap", "scale", "block_s", "interpret"))
def decode_attention(q, k_cache, v_cache, k_positions, q_position, *,
                     window: int = 0, softcap: float = 0.0, scale=None,
                     block_s: int = 512, interpret: Optional[bool] = None):
    return _decode(q, k_cache, v_cache, k_positions, q_position,
                   window=window, softcap=softcap, scale=scale,
                   block_s=block_s, interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, *, chunk: int = 64,
         interpret: Optional[bool] = None):
    return _wkv6(r, k, v, w, u, chunk=chunk,
                 interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("chunk", "block_r", "interpret"))
def rglru_scan(a, x, h0=None, *, chunk: int = 128, block_r: int = 512,
               interpret: Optional[bool] = None):
    return _rglru(a, x, h0, chunk=chunk, block_r=block_r,
                  interpret=_auto_interpret(interpret))
