"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are deliberately naive (full score matrices, sequential scans) — the
kernel tests sweep shapes/dtypes and assert_allclose against them.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0, scale: Optional[float] = None):
    """q: [B, H, S, hd]; k, v: [B, K, S, hd] -> [B, H, S, hd] (naive)."""
    B, H, S, hd = q.shape
    K = k.shape[1]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kp <= qp
    if window > 0:
        mask &= (qp - kp) < window
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vv.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, k_positions, q_position, *,
                         window: int = 0, softcap: float = 0.0,
                         scale: Optional[float] = None):
    """q: [B, H, hd]; caches [B, K, S, hd]; -> [B, H, hd]."""
    B, H, hd = q.shape
    K, S = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, K, G, hd)
    logits = jnp.einsum("bkgd,bksd->bkgs", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    valid = (k_positions >= 0) & (k_positions <= q_position[:, None])
    if window > 0:
        valid &= (q_position[:, None] - k_positions) < window
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


def wkv6_ref(r, k, v, w, u):
    """Sequential WKV6.  r,k,v,w: [B,T,H,hd]; u: [H,hd] -> y [B,T,H,hd] f32."""
    B, T, H, hd = r.shape
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(S, xs):
        rt, kt, vt, wt = xs
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        y = jnp.einsum("bhi,bhij->bhj", rt, S + uf[None, :, :, None] * kv)
        return wt[..., None] * S + kv, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, wf))
    _, ys = jax.lax.scan(step, jnp.zeros((B, H, hd, hd), jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1)


def rglru_scan_ref(a, x, h0=None):
    """Sequential diagonal recurrence.  a, x: [B,T,R] -> h traj [B,T,R] f32."""
    B, T, R = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, R), jnp.float32)

    def step(h, xs):
        at, xt = xs
        h = at.astype(jnp.float32) * h + xt.astype(jnp.float32)
        return h, h

    xs = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(x, 1, 0))
    _, hs = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(hs, 0, 1)
