"""``python -m repro.check`` — the static plan linter's CLI package.

The implementation lives in :mod:`repro.analysis.cli`; this package
exists so the linter has a short, stable invocation name.
"""
from repro.analysis.cli import main  # noqa: F401
