"""Training step: loss -> grads -> optimizer update, mesh-aware."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.registry import Model
from repro.training import optim


class TrainState(dict):
    """{"params": ..., "opt": ...} — plain dict for easy pytree handling."""


def init_train_state(model: Model, key, opt_cfg: Optional[optim.OptConfig]
                     = None) -> Dict[str, Any]:
    params = model.init(key)
    opt_name = model.cfg.optimizer
    opt_init, _ = optim.make_optimizer(opt_name, opt_cfg)
    return {"params": params, "opt": opt_init(params)}


def make_train_step(model: Model, opt_cfg: Optional[optim.OptConfig] = None):
    """Returns train_step(state, batch) -> (state, metrics).

    When ``cfg.grad_accum > 1`` the global batch is split into microbatches
    scanned sequentially with f32 grad accumulation — this is what fits the
    480B-class MoE training under 16 GB/chip (DESIGN.md §4)."""
    _, opt_update = optim.make_optimizer(model.cfg.optimizer, opt_cfg)
    accum = max(1, model.cfg.grad_accum)

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, remat=True)
        return loss, metrics

    def train_step(state, batch):
        params = state["params"]
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            micro = jax.tree.map(
                lambda t: t.reshape((accum, t.shape[0] // accum)
                                    + t.shape[1:]), batch)

            adt = jnp.dtype(model.cfg.accum_dtype)

            def micro_step(acc, mb):
                (l, met), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                acc = jax.tree.map(
                    lambda a, gi: a + (gi / accum).astype(adt), acc, g)
                return acc, (l, met)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, adt), params)
            grads, (losses, metrics) = jax.lax.scan(micro_step, zeros, micro)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, metrics)
        new_params, new_opt, gnorm = opt_update(params, grads, state["opt"])
        metrics = dict(metrics)
        metrics.update({"loss": loss, "grad_norm": gnorm})
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_eval_step(model: Model):
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch, remat=False)
        return {"loss": loss, **metrics}
    return eval_step
