"""Minimal but real checkpointing: flat-key .npz of the full state pytree.

Saves params + optimizer state + step with dtype preservation (bf16 stored
as uint16 view).  Path layout: <dir>/step_<n>.npz plus a LATEST pointer, with
atomic rename so a crashed save never corrupts the latest checkpoint.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def save(path: str, state, step: int) -> str:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(state)
    arrays = {}
    meta = {}
    for k, v in flat.items():
        a = np.asarray(v)
        if a.dtype == jnp.bfloat16:
            arrays[k] = a.view(np.uint16)
            meta[k] = "bfloat16"
        else:
            arrays[k] = a
            meta[k] = str(a.dtype)
    fname = os.path.join(path, f"step_{step}.npz")
    tmp = fname + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, __meta__=json.dumps(meta), **arrays)
    os.replace(tmp, fname)
    latest = os.path.join(path, "LATEST")
    with open(latest + ".tmp", "w") as f:
        f.write(str(step))
    os.replace(latest + ".tmp", latest)
    return fname


def latest_step(path: str) -> int:
    with open(os.path.join(path, "LATEST")) as f:
        return int(f.read().strip())


def restore(path: str, like, step: int = -1):
    """Restore into the structure of ``like`` (a template pytree)."""
    if step < 0:
        step = latest_step(path)
    data = np.load(os.path.join(path, f"step_{step}.npz"), allow_pickle=False)
    meta = json.loads(str(data["__meta__"]))
    flat_like = _flatten(like)
    out = {}
    for k in flat_like:
        a = data[k]
        if meta[k] == "bfloat16":
            a = a.view(jnp.bfloat16)
        out[k] = jnp.asarray(a)
    # rebuild tree
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    keys = [_SEP.join(_path_str(p) for p in path) for path, _ in paths_leaves]
    return jax.tree_util.tree_unflatten(treedef, [out[k] for k in keys])
