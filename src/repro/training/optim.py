"""Optimizers: AdamW and Adafactor (for the 400-480B MoEs, DESIGN.md §4).

Implemented from scratch (no optax dependency).  State pytrees mirror the
param pytree so they inherit the same PartitionSpecs (ZeRO-style: the FSDP
``data``-axis sharding on params divides optimizer state per-chip memory by
the full mesh size).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # adamw | adafactor | sgdm
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    # adafactor
    factored_min_dim: int = 128
    decay_rate: float = 0.8


def schedule(cfg: OptConfig, step):
    """Linear warmup then constant (kept simple; cosine in train loop opts)."""
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1),
                       1.0)
    return cfg.lr * warm


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(
        lambda t: jnp.sum(jnp.square(t.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def clip_scale(tree, max_norm: float):
    """Scalar clip factor — applied per-leaf inside the update to avoid
    materializing a scaled copy of the whole grad tree (peak-memory)."""
    norm = global_norm(tree)
    return jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9)), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    scale, gnorm = clip_scale(grads, cfg.grad_clip)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / (1 - b1 ** step.astype(jnp.float32))
        vh = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, gnorm


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; no first moment — PaLM-style)
# ---------------------------------------------------------------------------
def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 128 and p.shape[-2] >= 128


def adafactor_init(params):
    def init(p):
        if _factored(p):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"v": jax.tree.map(init, params,
                              is_leaf=lambda x: isinstance(x, jax.Array)),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    scale, gnorm = clip_scale(grads, cfg.grad_clip)
    beta = 1.0 - (step.astype(jnp.float32) ** -cfg.decay_rate)

    def upd(p, g, v):
        g = g.astype(jnp.float32) * scale
        g2 = jnp.square(g) + 1e-30
        if _factored(p):
            vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
            denom = (vr[..., None] / jnp.mean(vr, axis=-1, keepdims=True)[..., None]
                     ) * vc[..., None, :]
            delta = g * jax.lax.rsqrt(denom + 1e-30)
            new_v = {"vr": vr, "vc": vc}
        else:
            vv = beta * v["v"] + (1 - beta) * g2
            delta = g * jax.lax.rsqrt(vv + 1e-30)
            new_v = {"v": vv}
        # update clipping (RMS <= 1), per Adafactor
        rms = jnp.sqrt(jnp.mean(jnp.square(delta)) + 1e-30)
        delta = delta / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), new_v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_v = tdef.flatten_up_to(state["v"])
    # chain leaf updates with optimization_barrier so XLA does not overlap
    # the f32 temporaries of several GB-scale expert leaves (peak memory)
    outs = []
    token = gnorm
    order = sorted(range(len(flat_p)), key=lambda i: -flat_p[i].size)
    results = [None] * len(flat_p)
    for i in order:
        g = jax.lax.optimization_barrier((flat_g[i], token))[0]
        new_p, new_v_leaf = upd(flat_p[i], g, flat_v[i])
        token = jax.lax.optimization_barrier(
            (jnp.zeros((), jnp.float32), new_p))[0]
        results[i] = (new_p, new_v_leaf)
    new_params = tdef.unflatten([r[0] for r in results])
    new_v = tdef.unflatten([r[1] for r in results])
    return new_params, {"v": new_v, "step": step}, gnorm


# ---------------------------------------------------------------------------
def make_optimizer(name: str, cfg: Optional[OptConfig] = None):
    cfg = cfg or OptConfig(name=name)
    if name == "adamw":
        return adamw_init, lambda p, g, s: adamw_update(p, g, s, cfg)
    if name == "adafactor":
        return adafactor_init, lambda p, g, s: adafactor_update(p, g, s, cfg)
    raise ValueError(f"unknown optimizer {name}")
