"""Synthetic LM data pipeline (deterministic, seeded, host-side).

Produces next-token-prediction batches from a synthetic "corpus": a mixture
of repeated n-gram motifs + noise so tiny models can visibly learn (loss
drops below the uniform-entropy floor within a few hundred steps), which the
end-to-end example (examples/train_small.py) asserts.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    motif_len: int = 8
    num_motifs: int = 64
    noise_prob: float = 0.1


class SyntheticLM:
    """Iterator of {"tokens": [B, S], "labels": [B, S]} int32 batches."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.motifs = self.rng.integers(
            0, cfg.vocab_size, size=(cfg.num_motifs, cfg.motif_len),
            dtype=np.int32)

    def _sequence(self) -> np.ndarray:
        cfg = self.cfg
        out = np.empty(cfg.seq_len + 1, np.int32)
        i = 0
        while i < cfg.seq_len + 1:
            m = self.motifs[self.rng.integers(cfg.num_motifs)]
            n = min(len(m), cfg.seq_len + 1 - i)
            out[i:i + n] = m[:n]
            i += n
        noise = self.rng.random(cfg.seq_len + 1) < cfg.noise_prob
        out[noise] = self.rng.integers(0, cfg.vocab_size, noise.sum())
        return out

    def batch(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        seqs = np.stack([self._sequence() for _ in range(cfg.batch_size)])
        return {"tokens": seqs[:, :-1].astype(np.int32),
                "labels": seqs[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch()
