"""Paper Fig 7: data locality — naive vs fusion-only vs fusion+dynamic
dispatch, varying object size.  Expectation: order-of-magnitude win for
large objects with both rewrites on (cache hits avoid modeled transfers)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import percentile, row, run_requests
from repro.core.dataflow import Dataflow
from repro.core.table import Table
from repro.runtime.netmodel import NetModel
from repro.runtime.runtime import Runtime


def _flow():
    """pick object -> lookup -> compute (paper's representative pipeline)."""
    def pick(i: int) -> tuple[int, str]:
        return i, f"obj{i % 12}"

    def compute(i: int, key: str, lookup) -> float:
        return float(np.sum(lookup))

    fl = Dataflow([("i", int)])
    lk = fl.map(pick, names=["i", "key"]).lookup("key", column=True)
    fl.output = lk.map(compute, names=["s"])
    return fl


def run(n_requests: int = 30):
    rows = []
    net = NetModel(latency_s=0.5e-3, bandwidth=1e9)
    for size_kb in (64, 8192):
        results = {}
        for mode, flags in (("naive", {}),
                            ("fusion", {"fusion": True}),
                            ("fusion+dispatch", {"locality": True,
                                                 "fusion": True})):
            rt = Runtime(n_cpu=4, net=net, cache_bytes=30 << 20)
            try:
                obj = np.zeros(size_kb * 1024 // 8, np.float64)
                for i in range(12):
                    rt.kvs.put(f"obj{i}", obj, charge=False)
                fl = _flow()
                fl.deploy(rt, **flags)
                # warm caches (paper does one pass first)
                for i in range(12):
                    fl.execute(Table([("i", int)],
                                     [(i,)])).result(timeout=60)
                ls = run_requests(
                    lambda i: fl.execute(Table([("i", int)],
                                               [(i,)])).result(timeout=60),
                    n_requests)
                results[mode] = ls
            finally:
                rt.stop()
        base = percentile(results["naive"], 50)
        for mode, ls in results.items():
            rows.append(row(
                f"locality/{size_kb}KB/{mode}", ls,
                f"speedup={base / percentile(ls, 50):.2f}x"))
    return rows


def check_flows():
    """Static-verifier hook (``python -m repro.check``)."""
    return [{"name": "locality", "flow": _flow(),
             "compile": {"fusion": True, "locality": True},
             "sample": Table([("i", int)], [(1,)])}]
