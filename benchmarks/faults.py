"""Chaos benchmark: the serving chain under injected executor faults.

The same pipeline as the overload benchmark (a batched-jitted GPU pair
feeding a fixed-service-time CPU bottleneck, so capacity is known in
closed form) is driven OPEN LOOP at half capacity — comfortably inside
the envelope, so every latency/outcome effect in the sweep is caused by
the injected faults, not by saturation.  Each sweep point installs a
seeded :class:`~repro.serving.faults.FaultPlan` applying crash + straggle
+ transient faults, each at the point's per-kind rate (so the labeled
rate triples when combined), with straggler hedging armed from the same
latency curves the admission gate models with.

What the CI gate asserts, per point:

* **zero hangs** — every offered request resolves with a TYPED outcome
  (ok | shed | expired | transient-failure) inside the driver's timeout:
  ``unresolved == 0`` and ``untyped_errors == 0`` even at the highest
  fault rate;
* **reconciliation** — ``offered == ok + shed + expired + failed``; the
  fault counters (injected vs detected crashes, retries, hedges) are
  internally consistent; every batcher returns to quiescent
  (``drained``), i.e. accepted-minus-completed accounting survived every
  crash/requeue/hedge path;
* **SLO under low fault rate** — interactive p99 stays inside the SLO
  at the low-fault point: recovery (redispatch + hedging) absorbs
  occasional faults without blowing the tail;
* **zero re-traces** — fault recovery re-executes already-compiled
  executables; no XLA tracing on the hot path;
* **no fault-free regression** — the 0-rate point's p50 is the price of
  the fault-tolerance machinery itself (tokens, idempotence journal,
  hedge timers); CI compares it against the overload benchmark's 0.5x
  point.
"""
from __future__ import annotations

import gc
import json
import threading
import time
from typing import Dict, List, Optional

from benchmarks.common import percentile, row

try:
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None

SERVICE_S = 0.01          # per-row service time of the CPU bottleneck
N_CPU = 2                 # capacity = N_CPU / SERVICE_S = 200 rows/s
SLO_S = 0.6               # interactive deadline == the SLO under test
OFFERED_FRAC = 0.5        # drive at half capacity: faults, not overload
HANG_S = 0.25             # injected straggle duration
HEDGE_FACTOR = 3.0        # hedge once past 3x the bottleneck's p99
MAX_BATCH = 4


def _g1(x: "jax.Array") -> "jax.Array":
    return x * 2.0


def _g2(x: "jax.Array") -> "jax.Array":
    return x + 1.0


def _cpu_slow(x: "jax.Array") -> "jax.Array":
    time.sleep(SERVICE_S)
    return jnp.asarray(x)


def _build_flow():
    from repro.core.dataflow import Dataflow
    fl = Dataflow([("x", jax.Array)])
    fl.output = fl.map(_g1, names=["x"], gpu=True, batching=True) \
        .map(_g2, names=["x"], gpu=True, batching=True) \
        .map(_cpu_slow, names=["x"], batching=True)
    return fl


def _sample():
    from repro.core.table import Table
    return Table([("x", jax.Array)], [(jnp.ones(8, jnp.float32),)])


def _profile_and_config(dep):
    """Synthetic-but-honest curves matching what each op actually costs
    (the same construction the overload benchmark gates with): one
    source of truth for the admission estimate AND the hedge delays."""
    from repro.profiling import (BucketStats, FlowProfile, NodeConfig,
                                 OpLatencyCurve, PlanConfig)
    curves = {}
    cfg = PlanConfig(nodes={})
    for o in dep.plan.ops:
        per_row = SERVICE_S if o.placement != "gpu" else 1e-4
        c = OpLatencyCurve(key=o.op_id, name=o.op.name, per_row_s=per_row)
        for bkt in (1, 2, 4):
            c.buckets[bkt] = BucketStats(
                mean_s=per_row * bkt, p99_s=per_row * bkt * 1.2,
                cv=0.05, runs=3, out_bytes=64 * bkt)
        curves[o.op_id] = c
        cfg.nodes[o.op_id] = NodeConfig(
            max_batch=MAX_BATCH, batch_wait_ms=2.0, batched_lowering=True,
            target_replicas=N_CPU)
    return FlowProfile(curves=curves), cfg


def _make_admission(dep, rt, profile, cfg):
    from repro.serving.admission import AdmissionController, ClassPolicy
    classes = {"interactive": ClassPolicy("interactive", priority=2,
                                          default_deadline_s=SLO_S)}
    return AdmissionController(dep.plan, profile, cfg, net=rt.net,
                               classes=classes)


def _drive_point(rt, name: str, rate_hz: float, duration_s: float):
    """Open-loop paced driver: outcomes recorded by done-callbacks
    registered at send time; ``unresolved`` counts futures that did not
    resolve inside the timeout — the hangs fault tolerance forbids."""
    from repro.serving.admission import DeadlineExceeded, Overloaded
    from repro.serving.retry import Transient
    lock = threading.Lock()
    lat: List[float] = []
    counts = {"sent": 0, "ok": 0, "shed": 0, "expired": 0, "failed": 0,
              "errors": 0, "unresolved": 0}
    futs = []
    i = 0
    t_start = time.perf_counter()
    while time.perf_counter() - t_start < duration_s:
        t_send = time.perf_counter()
        f = rt.call_dag(name, _sample(), klass="interactive")
        counts["sent"] += 1

        def _done(fut, t0=t_send):
            dt = time.perf_counter() - t0
            try:
                exc = fut.exception()
            except BaseException as e:   # pragma: no cover
                exc = e
            with lock:
                if exc is None:
                    counts["ok"] += 1
                    lat.append(dt)
                elif isinstance(exc, DeadlineExceeded):
                    counts["expired"] += 1
                elif isinstance(exc, Overloaded):
                    counts["shed"] += 1
                elif isinstance(exc, Transient):
                    # typed fault delivery: retries exhausted or no
                    # healthy replica in time — a FAILURE, but a typed,
                    # prompt one
                    counts["failed"] += 1
                else:
                    counts["errors"] += 1
        f.add_done_callback(_done)
        futs.append(f)
        i += 1
        next_t = t_start + i / rate_hz
        pause = next_t - time.perf_counter()
        if pause > 0:
            time.sleep(pause)
    for f in futs:
        try:
            f.result(timeout=30)
        except BaseException:
            pass
    with lock:
        done = sum(counts[k] for k in
                   ("ok", "shed", "expired", "failed", "errors"))
        counts["unresolved"] = counts["sent"] - done
    return lock, lat, counts


def _drained(rt, timeout_s: float = 10.0):
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < timeout_s:
        with rt._batchers_lock:
            bs = list(rt._batchers.values())
        if all(b.quiescent() for b in bs):
            return True, time.perf_counter() - t0
        time.sleep(0.02)
    return False, time.perf_counter() - t0


def _series_count(rt, key: str) -> int:
    return len(rt.metrics_snapshot().get(key, []))


def run(duration_s: float = 2.5,
        rates=(0.0, 0.01, 0.02, 0.05),
        json_path: Optional[str] = None) -> List[str]:
    if jax is None:  # pragma: no cover
        return ["faults_skipped,0.0,no jax"]
    from repro.core.lowering import EXECUTABLE_CACHE, BatchedJittedFuse
    from repro.runtime.netmodel import NetModel
    from repro.runtime.runtime import Runtime
    from repro.serving.faults import FaultPlan, install_hedging

    capacity = N_CPU / SERVICE_S
    offered = OFFERED_FRAC * capacity
    rt = Runtime(n_cpu=N_CPU, n_gpu=1, net=NetModel(scale=0.0),
                 max_batch=MAX_BATCH, batch_wait_ms=2.0,
                 hang_timeout_s=2.0, detector_interval_s=0.02)
    rows: List[str] = []
    try:
        fl = _build_flow()
        dep = fl.deploy(rt, fusion=True, name="faults_bench")
        assert any(isinstance(o.op, BatchedJittedFuse)
                   for o in dep.plan.ops), "gpu pair did not lower"
        profile, cfg = _profile_and_config(dep)
        # straggler hedging from the SAME curves the gate models with;
        # delays sized for a full batch so healthy batches never hedge
        from repro.serving.faults import hedge_delays_from_profile
        delays = hedge_delays_from_profile(dep, profile,
                                           factor=HEDGE_FACTOR,
                                           batch=MAX_BATCH)
        for node_name, d in delays.items():
            rt.configure_hedging("faults_bench", node_name, d)

        # warm every executable variant off the clock, then snapshot the
        # trace counter: recovery re-executions must hit the cache
        for _ in range(4):
            rt.call_dag("faults_bench", _sample(),
                        klass="interactive").result(timeout=30)
        _drive_point(rt, "faults_bench", offered, 0.4)
        _drained(rt)
        traces_warm = EXECUTABLE_CACHE.traces()

        points = []
        gc.collect()
        for i, fr in enumerate(rates):
            adm = _make_admission(dep, rt, profile, cfg)
            rt.set_admission("faults_bench", adm)
            injector = None
            if fr > 0.0:
                injector = rt.set_fault_plan(
                    FaultPlan(seed=1000 + i)
                    .crash(rate=fr).hang(rate=fr, hang_s=HANG_S)
                    .transient(rate=fr))
            m0 = {k: _series_count(rt, k) for k in (
                "faults/crash_t", "faults/wedge_t", "faults/requeued_t",
                "dag/faults_bench/retry_t", "dag/faults_bench/hedge_t")}
            f0 = dict(rt.pool.fault_counts)
            gc.collect()
            gc.disable()
            try:
                lock, lat, counts = _drive_point(
                    rt, "faults_bench", offered, duration_s)
            finally:
                gc.enable()
            rt.set_fault_plan(None)
            drained, drain_s = _drained(rt)

            with lock:
                ls = sorted(lat)
                resolved_typed = (counts["unresolved"] == 0
                                  and counts["errors"] == 0)
                reconciled = (counts["ok"] + counts["shed"]
                              + counts["expired"] + counts["failed"]
                              == counts["sent"])
                point = {
                    "fault_rate_per_kind": fr,
                    "fault_rate_combined": 3 * fr,
                    "offered_rps_target": offered,
                    "duration_s": duration_s,
                    "counts": dict(counts),
                    "p50_ms": (percentile(ls, 50) * 1e3 if ls else None),
                    "p99_ms": (percentile(ls, 99) * 1e3 if ls else None),
                    "served_frac": (counts["ok"] / counts["sent"]
                                    if counts["sent"] else None),
                    "injected": (injector.snapshot() if injector
                                 else {"crash": 0, "hang": 0,
                                       "transient": 0}),
                    "detected": {
                        k: rt.pool.fault_counts[k] - f0[k]
                        for k in ("crash", "wedge", "requeued",
                                  "replaced", "lost")},
                    "crashes": (_series_count(rt, "faults/crash_t")
                                - m0["faults/crash_t"]),
                    "wedges": (_series_count(rt, "faults/wedge_t")
                               - m0["faults/wedge_t"]),
                    "retries": (_series_count(
                        rt, "dag/faults_bench/retry_t")
                        - m0["dag/faults_bench/retry_t"]),
                    "hedges": (_series_count(
                        rt, "dag/faults_bench/hedge_t")
                        - m0["dag/faults_bench/hedge_t"]),
                    "drained": drained,
                    "drain_s": drain_s,
                    "resolved_typed": resolved_typed,
                    "reconciled": reconciled,
                }
            points.append(point)
            rt.set_admission("faults_bench", None)

            rows.append(row(
                f"faults_{3 * fr:g}",
                (point["p99_ms"] or 0.0) * 1e3,
                f"p50={None if point['p50_ms'] is None else round(point['p50_ms'], 1)}ms "
                f"p99={None if point['p99_ms'] is None else round(point['p99_ms'], 1)}ms "
                f"crashes={point['crashes']} retries={point['retries']} "
                f"hedges={point['hedges']} failed={counts['failed']} "
                f"typed={resolved_typed} drained={drained}"))

        retraces = EXECUTABLE_CACHE.traces() - traces_warm
        bad = sum(1 for p in points
                  if not (p["drained"] and p["reconciled"]
                          and p["resolved_typed"]))
        rows.append(row(
            "faults_integrity", float(bad + retraces),
            f"bad_points={bad} retraces_post_warm={retraces} "
            f"points={len(points)}"))

        result = {
            "suite": "faults",
            "pipeline": ("vjit[g1,g2](gpu, batched) -> "
                         f"cpu_sleep({SERVICE_S * 1e3:.0f}ms/row)"),
            "capacity_rps": capacity,
            "offered_rps": offered,
            "slo_ms": SLO_S * 1e3,
            "hang_s": HANG_S,
            "hedge_factor": HEDGE_FACTOR,
            "hedge_delays_ms": {k: v * 1e3 for k, v in delays.items()},
            "duration_s_per_point": duration_s,
            "points": points,
            "retraces_post_warm": retraces,
            "cache_stats": EXECUTABLE_CACHE.stats(),
        }
        if json_path:
            with open(json_path, "w") as f:
                json.dump(result, f, indent=1, sort_keys=True,
                          default=str)
        return rows
    finally:
        rt.stop()
        time.sleep(0.3)


def check_flows():
    """Static-verifier hook (``python -m repro.check``)."""
    return [{"name": "faults", "flow": _build_flow(),
             "compile": {"fusion": True}, "sample": _sample(),
             "max_batch": MAX_BATCH}]
