"""Overload-protection benchmark: offered-load sweep across the
admission gate (``repro.serving.admission``) + deadline-aware batching.

The serving chain is the smallest shape that exercises every layer the
protection stack touches:

* a fused, batched-jitted GPU pair (``BatchedJittedFuse``) — the stage
  whose executable-cache behaviour we account for (degraded requests
  route to its per-row variant; padding buckets bound recompiles);
* a CPU map with a fixed per-row service time — the *deliberate*
  bottleneck, so capacity is known in closed form
  (``n_cpu / SERVICE_S``) and "3x capacity" means what it says.

Two request classes share the deployment, the canonical protected/
sheddable split:

* ``interactive`` (priority 2, deadline = SLO): never shed, never
  degraded — the class the gate exists to protect;
* ``best_effort`` (priority 0, token bucket at 10% of capacity, a tight
  deadline, a ``DegradePolicy``): degrades first, sheds first.

For each multiplier in the sweep an open-loop Poisson-free paced driver
offers ``mult * capacity`` req/s for ``duration_s`` (open loop: arrival
times never wait on completions — the backlog is real).  Per point we
report per-class goodput / p50 / p99, shed + degrade + expiry counts,
and four integrity signals the CI gate asserts on at 3x:

* ``shed_fail_p99_ms`` — sheds must fail in a fraction of the SLO
  budget (fast-fail, not queue-then-die);
* ``expired_overrun_p99_ms`` — p99 of (failure latency − own deadline)
  for expired requests: expiry is detected promptly after the deadline
  passes, not discovered at dispatch minutes later;
* ``drained`` — every batcher returns to quiescent after the burst (no
  wedged accounting);
* ``reconciled`` — gate counters agree with observed outcomes:
  offered == admitted + degraded + shed, and every offered request
  resolved exactly once (ok | shed | expired), zero untyped errors.

``retraces_post_warm`` (top level) counts executable-cache traces taken
during the sweep itself, after a short warm-up burst: degraded serving
must route to *already-compiled* variants, never pay XLA tracing on the
overloaded hot path.
"""
from __future__ import annotations

import gc
import json
import threading
import time
from typing import Dict, List, Optional

from benchmarks.common import percentile, row

try:
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None

SERVICE_S = 0.01          # per-row service time of the CPU bottleneck
N_CPU = 2                 # capacity = N_CPU / SERVICE_S = 200 rows/s
SLO_S = 0.6               # interactive deadline == the SLO under test
BE_DEADLINE_S = 0.05      # best_effort deadline: tight by design
INTERACTIVE_EVERY = 5     # 20% of offered traffic is interactive


def _g1(x: "jax.Array") -> "jax.Array":
    return x * 2.0


def _g2(x: "jax.Array") -> "jax.Array":
    return x + 1.0


def _cpu_slow(x: "jax.Array") -> "jax.Array":
    time.sleep(SERVICE_S)
    # re-assert device type: the upstream batched gpu stage can hand
    # rows across the host boundary as numpy after unpadding
    return jnp.asarray(x)


def _build_flow():
    from repro.core.dataflow import Dataflow
    fl = Dataflow([("x", jax.Array)])
    # two gpu maps fuse + lower to one BatchedJittedFuse; the cpu sleep
    # map stays un-fused (placement mismatch) and un-jitted (cpu-placed)
    fl.output = fl.map(_g1, names=["x"], gpu=True, batching=True) \
        .map(_g2, names=["x"], gpu=True, batching=True) \
        .map(_cpu_slow, names=["x"], batching=True)
    return fl


def _sample():
    from repro.core.table import Table
    return Table([("x", jax.Array)], [(jnp.ones(8, jnp.float32),)])


def _make_admission(dep, rt):
    """An honest gate: per-op curves matching what each op actually
    costs, so the M/M/c estimate — and therefore every shed/degrade
    decision in the sweep — comes from the real critical path."""
    from repro.core.lowering import DegradePolicy
    from repro.profiling import (BucketStats, FlowProfile, NodeConfig,
                                 OpLatencyCurve, PlanConfig)
    from repro.serving.admission import AdmissionController, ClassPolicy
    curves = {}
    cfg = PlanConfig(nodes={})
    for o in dep.plan.ops:
        per_row = SERVICE_S if o.placement != "gpu" else 1e-4
        c = OpLatencyCurve(key=o.op_id, name=o.op.name, per_row_s=per_row)
        for bkt in (1, 2, 4):
            c.buckets[bkt] = BucketStats(
                mean_s=per_row * bkt, p99_s=per_row * bkt * 1.2,
                cv=0.05, runs=3, out_bytes=64 * bkt)
        curves[o.op_id] = c
        cfg.nodes[o.op_id] = NodeConfig(
            max_batch=4, batch_wait_ms=2.0, batched_lowering=True,
            target_replicas=N_CPU)
    classes = {
        "interactive": ClassPolicy("interactive", priority=2,
                                   default_deadline_s=SLO_S),
        # the bucket sits ABOVE capacity's best_effort share so the
        # estimator — not a static rate cap — is the binding constraint
        # under overload: we want to see degrade + deadline expiry, not
        # just rate_limit sheds
        "best_effort": ClassPolicy(
            "best_effort", priority=0,
            rate=0.75 * (N_CPU / SERVICE_S), burst=20,
            degrade=DegradePolicy(per_row=True, bucket_cap=4),
            default_deadline_s=BE_DEADLINE_S),
    }
    return AdmissionController(dep.plan, FlowProfile(curves=curves), cfg,
                               net=rt.net, classes=classes)


def _drive_point(rt, name: str, rate_hz: float, duration_s: float):
    """Open-loop paced driver for one sweep point.  Outcomes/latencies
    are recorded by done-callbacks registered AT SEND TIME (a post-hoc
    collection loop would time future-resolution, not request latency)."""
    from repro.serving.admission import DeadlineExceeded, Overloaded
    lock = threading.Lock()
    lat: Dict[str, List[float]] = {"interactive": [], "best_effort": []}
    shed_fail: List[float] = []
    expired_overrun: Dict[str, List[float]] = {
        "interactive": [], "best_effort": []}
    counts = {k: {"sent": 0, "ok": 0, "shed": 0, "expired": 0,
                  "errors": 0}
              for k in ("interactive", "best_effort")}
    deadline_of = {"interactive": SLO_S, "best_effort": BE_DEADLINE_S}
    futs = []
    i = 0
    t_start = time.perf_counter()
    while time.perf_counter() - t_start < duration_s:
        klass = ("interactive" if i % INTERACTIVE_EVERY == 0
                 else "best_effort")
        t_send = time.perf_counter()
        f = rt.call_dag(name, _sample(), klass=klass)
        counts[klass]["sent"] += 1

        def _done(fut, t0=t_send, k=klass):
            dt = time.perf_counter() - t0
            try:
                exc = fut.exception()
            except BaseException as e:   # pragma: no cover
                exc = e
            with lock:
                if exc is None:
                    counts[k]["ok"] += 1
                    lat[k].append(dt)
                elif isinstance(exc, DeadlineExceeded):
                    counts[k]["expired"] += 1
                    expired_overrun[k].append(dt - deadline_of[k])
                elif isinstance(exc, Overloaded):
                    counts[k]["shed"] += 1
                    shed_fail.append(dt)
                else:
                    counts[k]["errors"] += 1
        f.add_done_callback(_done)
        futs.append(f)
        i += 1
        # open loop: pace arrivals off the wall clock, never completions
        next_t = t_start + i / rate_hz
        pause = next_t - time.perf_counter()
        if pause > 0:
            time.sleep(pause)
    for f in futs:                      # wait out every in-flight future
        try:
            f.result(timeout=30)
        except BaseException:
            pass
    return lock, lat, shed_fail, expired_overrun, counts


def _drained(rt, timeout_s: float = 10.0):
    """(drained?, seconds-to-drain): every batcher back to quiescent."""
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < timeout_s:
        with rt._batchers_lock:
            bs = list(rt._batchers.values())
        if all(b.quiescent() for b in bs):
            return True, time.perf_counter() - t0
        time.sleep(0.02)
    return False, time.perf_counter() - t0


def run(duration_s: float = 2.5,
        multipliers=(0.5, 1.0, 2.0, 3.0),
        json_path: Optional[str] = None) -> List[str]:
    if jax is None:  # pragma: no cover
        return ["overload_skipped,0.0,no jax"]
    from repro.core.lowering import EXECUTABLE_CACHE, BatchedJittedFuse
    from repro.runtime.netmodel import NetModel
    from repro.runtime.runtime import Runtime

    capacity = N_CPU / SERVICE_S
    rt = Runtime(n_cpu=N_CPU, n_gpu=1, net=NetModel(scale=0.0),
                 max_batch=4, batch_wait_ms=2.0)
    rows: List[str] = []
    try:
        fl = _build_flow()
        dep = fl.deploy(rt, fusion=True, name="overload_bench")
        assert any(isinstance(o.op, BatchedJittedFuse)
                   for o in dep.plan.ops), "gpu pair did not lower"
        adm = _make_admission(dep, rt)
        rt.set_admission("overload_bench", adm)

        # warm every executable variant the sweep can touch (batch
        # padding buckets AND the degraded per-row route) with a short
        # off-the-clock burst, then snapshot the trace counter: any
        # trace taken DURING the sweep is a protection failure
        for _ in range(4):
            rt.call_dag("overload_bench", _sample(),
                        klass="interactive").result(timeout=30)
        _drive_point(rt, "overload_bench", 3.0 * capacity, 0.4)
        _drained(rt)
        rt.set_admission("overload_bench", None)
        traces_warm = EXECUTABLE_CACHE.traces()

        points = []
        gc.collect()
        for mult in multipliers:
            # a fresh gate per point: token buckets, arrival-rate window
            # and counters all start clean, so points are independent
            adm = _make_admission(dep, rt)
            rt.set_admission("overload_bench", adm)
            gc.collect()
            # a gen-2 GC pause mid-drive reads as a fake p99 outlier:
            # collect now, hold collection during the drive
            gc.disable()
            try:
                lock, lat, shed_fail, over, counts = _drive_point(
                    rt, "overload_bench", mult * capacity, duration_s)
            finally:
                gc.enable()
            drained, drain_s = _drained(rt)

            with lock:
                gate = adm.snapshot()
                ga = sum(v for k, v in gate.items()
                         if k.endswith("/admitted"))
                gd = sum(v for k, v in gate.items()
                         if k.endswith("/degraded"))
                gs = sum(v for k, v in gate.items()
                         if k.endswith("/shed"))
                go = sum(v for k, v in gate.items()
                         if k.endswith("/offered"))
                sent = sum(c["sent"] for c in counts.values())
                oks = sum(c["ok"] for c in counts.values())
                sheds = sum(c["shed"] for c in counts.values())
                expd = sum(c["expired"] for c in counts.values())
                errs = sum(c["errors"] for c in counts.values())
                reconciled = (go == sent
                              and ga + gd + gs == go
                              and gs == sheds
                              and ga + gd == oks + expd
                              and oks + sheds + expd + errs == sent)
                classes = {}
                for k, c in counts.items():
                    ls = sorted(lat[k])
                    classes[k] = {
                        **c,
                        "p50_ms": (percentile(ls, 50) * 1e3
                                   if ls else None),
                        "p99_ms": (percentile(ls, 99) * 1e3
                                   if ls else None),
                        "goodput_rps": c["ok"] / duration_s,
                        "served_frac": (c["ok"] / c["sent"]
                                        if c["sent"] else None),
                    }
                all_over = over["interactive"] + over["best_effort"]
                point = {
                    "multiplier": mult,
                    "offered_rps_target": mult * capacity,
                    "offered": sent,
                    "duration_s": duration_s,
                    "classes": classes,
                    "admitted": ga, "degraded": gd, "shed": gs,
                    "shed_fail_p99_ms": (percentile(sorted(shed_fail),
                                                    99) * 1e3
                                         if shed_fail else None),
                    "expired_overrun_p99_ms": (
                        percentile(sorted(all_over), 99) * 1e3
                        if all_over else None),
                    "errors": errs,
                    "drained": drained,
                    "drain_s": drain_s,
                    "reconciled": reconciled,
                }
            points.append(point)
            rt.set_admission("overload_bench", None)

            ip99 = classes["interactive"]["p99_ms"]
            rows.append(row(
                f"overload_{mult:g}x",
                (ip99 or 0.0) * 1e3,
                f"interactive p99={ip99 if ip99 is None else round(ip99, 1)}ms "
                f"goodput={classes['interactive']['goodput_rps']:.0f}rps "
                f"degraded={gd} shed={gs} expired={expd} "
                f"drained={drained}"))

        retraces = EXECUTABLE_CACHE.traces() - traces_warm
        bad = sum(1 for p in points
                  if not (p["drained"] and p["reconciled"]
                          and p["errors"] == 0))
        rows.append(row(
            "overload_integrity", float(bad + retraces),
            f"bad_points={bad} retraces_post_warm={retraces} "
            f"points={len(points)}"))

        result = {
            "suite": "overload",
            "pipeline": ("vjit[g1,g2](gpu, batched) -> "
                         f"cpu_sleep({SERVICE_S * 1e3:.0f}ms/row)"),
            "capacity_rps": capacity,
            "service_ms": SERVICE_S * 1e3,
            "slo_ms": SLO_S * 1e3,
            "best_effort_deadline_ms": BE_DEADLINE_S * 1e3,
            "interactive_share": 1.0 / INTERACTIVE_EVERY,
            "duration_s_per_point": duration_s,
            "points": points,
            "retraces_post_warm": retraces,
            "cache_stats": EXECUTABLE_CACHE.stats(),
        }
        if json_path:
            with open(json_path, "w") as f:
                json.dump(result, f, indent=1, sort_keys=True,
                          default=str)
        return rows
    finally:
        rt.stop()
        time.sleep(0.3)


def check_flows():
    """Static-verifier hook (``python -m repro.check``)."""
    return [{"name": "overload", "flow": _build_flow(),
             "compile": {"fusion": True}, "sample": _sample(),
             "max_batch": 4}]
