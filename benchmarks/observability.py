"""Observability benchmark: tracing overhead + attribution sanity.

The serving chain is a 3-node CPU pipeline (fast -> SLOW -> fast, fixed
per-row sleeps) so the ground truth is known in closed form: the middle
node IS the bottleneck, by construction.  Two questions:

* **overhead** — per-request p50/p99 of the same paced workload with
  tracing disabled vs head-sampling at 1% / 10% / 100% (fresh runtime
  per point, tail-keep always on).  Tracing is list-appends plus
  ``perf_counter`` calls on the request path; the CI gate asserts the
  10%-sampling p50 stays within 5% of the disabled baseline (with a
  small absolute guard for timer noise on shared runners).
* **attribution** — drive the 100%-sampled deployment with a deadline
  the chain cannot meet, fold the kept traces through
  ``repro.obs.attribution``, and check the dominant (node, component)
  is ``service`` at the deliberately slow middle node — the "which
  stage ate the budget" answer an operator acts on.

Integrity bits ride along: the Chrome exporter emits every span kind on
the hot path (admission / queue / exec / demux / batch + flow links),
and the executable cache takes ZERO fresh traces during the measured
sweep (tracing must never cause XLA recompiles).
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional

from benchmarks.common import percentile, row

FAST_S = 0.0002           # per-row service of the two fast stages
SLOW_S = 0.002            # per-row service of the deliberate bottleneck
PACE_S = 0.0035           # open-loop inter-arrival gap
MISS_DEADLINE_S = 0.010   # a deadline the full chain cannot meet


def _chain(name_tag: str):
    from repro.core.dataflow import Dataflow

    def fast(i: int) -> int:
        time.sleep(FAST_S)
        return i

    def slow(i: int) -> int:
        time.sleep(SLOW_S)
        return i

    fl = Dataflow([("i", int)])
    n1 = fl.map(fast, names=["i"], batching=True)
    n2 = n1.map(slow, names=["i"], batching=True)
    n3 = n2.map(fast, names=["i"], batching=True)
    fl.output = n3
    return fl


def _drive(rt, name: str, n: int, deadline_s: Optional[float] = None,
           pace_s: float = PACE_S) -> List[float]:
    """Paced open-loop workload; per-request latency stamped in the
    future's done-callback (arrival pacing never waits on completions)."""
    from repro.core.table import Table
    lats: List[float] = []
    pending = []
    for k in range(n):
        t0 = time.perf_counter()
        fut = rt.call_dag(name, Table([("i", int)], [(k,)]),
                          deadline_s=deadline_s)
        fut.add_done_callback(
            lambda f, t0=t0: lats.append(time.perf_counter() - t0))
        pending.append(fut)
        if pace_s:
            time.sleep(pace_s)
    for f in pending:
        try:
            f.result(timeout=10)
        except Exception:
            pass
    return lats


def _point(sample_rate: Optional[float], n: int) -> Dict[str, object]:
    """One sweep point on a FRESH runtime: None = tracing disabled."""
    from repro.obs import Tracer
    from repro.runtime.netmodel import NetModel
    from repro.runtime.runtime import Runtime
    tracer = Tracer(enabled=sample_rate is not None,
                    sample_rate=sample_rate or 0.0, capacity=1024)
    rt = Runtime(n_cpu=4, net=NetModel(scale=0.0), batch_wait_ms=1.0,
                 tracer=tracer)
    try:
        name = "obs-chain"
        _chain(name).deploy(rt, name=name)
        _drive(rt, name, n=max(8, n // 10))          # warm-up
        lats = _drive(rt, name, n=n)
        out: Dict[str, object] = {
            "sample_rate": sample_rate,
            "n": len(lats),
            "p50_ms": percentile(lats, 50) * 1e3,
            "p99_ms": percentile(lats, 99) * 1e3,
            "tracer": tracer.stats(),
        }
        return out, rt
    except BaseException:
        rt.stop()
        raise


def run(n_requests: int = 200,
        json_path: Optional[str] = None) -> List[str]:
    from repro.core.lowering import EXECUTABLE_CACHE
    from repro.obs import attribute, to_chrome_events

    traces_before = EXECUTABLE_CACHE.traces()
    rows: List[str] = []
    points: List[Dict[str, object]] = []
    keep_rt = None
    for rate in (None, 0.01, 0.1, 1.0):
        pt, rt = _point(rate, n_requests)
        points.append(pt)
        if rate == 1.0:
            keep_rt = rt                  # reused for the attribution run
        else:
            rt.stop()
        label = "off" if rate is None else f"{rate:g}"
        rows.append(row(f"obs_trace[{label}]", pt["p50_ms"] * 1e3,
                        f"p99={pt['p99_ms']:.2f}ms n={pt['n']}"))

    base = next(p for p in points if p["sample_rate"] is None)
    for p in points:
        if p["sample_rate"] is None:
            p["overhead_p50_pct"] = 0.0
            continue
        p["overhead_p50_pct"] = \
            (p["p50_ms"] / base["p50_ms"] - 1.0) * 100.0

    # -- attribution sanity on the 100%-sampled deployment -------------------
    # a BURST under a deadline the chain cannot meet: the slow node's
    # merged batch dispatches early (inside the budget) but its service
    # time alone blows the deadline, so every member misses the SLO with
    # an exec@slow-node span on its trace — deterministic ground truth
    name = "obs-chain"
    keep_rt.tracer.clear()
    _drive(keep_rt, name, n=max(16, n_requests // 4),
           deadline_s=MISS_DEADLINE_S, pace_s=0.0)
    kept = keep_rt.tracer.kept(name)
    att = attribute(kept, slo_only=True)
    dom = att.dominant()
    slow_node = next(n for n in keep_rt.dags[name].nodes if "2:" in n)
    dominant_ok = bool(dom and dom[0] == slow_node and dom[1] == "service")

    # -- exporter sanity: every hot-path span kind reaches the trace file ----
    links = {s.link for t in kept for s in t.spans if s.link is not None}
    events = to_chrome_events(kept, keep_rt.tracer.batch_spans(links))
    cats = {e.get("cat") for e in events if e.get("ph") == "X"}
    spans_ok = {"admission", "queue", "exec", "demux", "batch",
                "request"} <= cats
    keep_rt.stop()

    retraces = EXECUTABLE_CACHE.traces() - traces_before
    p10 = next(p for p in points if p["sample_rate"] == 0.1)
    rows.append(row(
        "obs_integrity",
        float((0 if dominant_ok else 1) + (0 if spans_ok else 1) + retraces),
        f"dominant_ok={dominant_ok} spans_ok={spans_ok} "
        f"retraces={retraces} overhead_p50_10pct="
        f"{p10['overhead_p50_pct']:.1f}%"))

    if json_path:
        doc = {
            "points": points,
            "attribution": {
                "n_traces": att.n_traces, "n_miss": att.n_miss,
                "n_shed": att.n_shed,
                "dominant": ({"node": dom[0], "component": dom[1],
                              "seconds": dom[2]} if dom else None),
                "expected_node": slow_node,
                "dominant_ok": dominant_ok,
            },
            "chrome_export": {"events": len(events),
                              "cats": sorted(c for c in cats if c),
                              "spans_ok": spans_ok},
            "retraces": retraces,
        }
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2)
    return rows


def check_flows():
    """Static-verifier hook (``python -m repro.check``)."""
    from repro.core.table import Table
    return [{"name": "observability", "flow": _chain("check"),
             "compile": {"fusion": True},
             "sample": Table([("i", int)], [(1,)])}]
