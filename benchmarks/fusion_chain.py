"""Paper Fig 4: operator fusion on linear chains — latency vs chain length
x payload size, fused vs unfused.  Expectation: unfused grows linearly with
chain length (data shipped per hop); fused stays flat.

Second section: XLA-level fusion on top of graph-level fusion.  A chain of
JAX map operators on GPU-class nodes is compiled by ``LowerJaxChainsPass``
into ONE jitted callable; we compare the interpreted fused path
(``jit_fusion=False``: one Python call + typecheck per sub-op per row)
against the jitted fused path (one XLA dispatch per row)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import percentile, row, run_requests
from repro.core.dataflow import Dataflow
from repro.core.table import Table
from repro.runtime.netmodel import NetModel
from repro.runtime.runtime import Runtime


def _chain_flow(length: int):
    def ident(x: np.ndarray) -> np.ndarray:
        return x
    fl = Dataflow([("x", np.ndarray)])
    node = fl.source
    for _ in range(length):
        node = node.map(ident, names=["x"])
    fl.output = node
    return fl


def _jax_chain_flow(length: int):
    def step(x: jax.Array) -> jax.Array:
        return jnp.tanh(x * 1.01 + 0.05) - 0.1 * x

    fl = Dataflow([("x", jax.Array)])
    node = fl.source
    for _ in range(length):
        node = node.map(step, names=["x"], gpu=True)
    fl.output = node
    return fl


def run_jit(n_requests: int = 30, length: int = 6, size_kb: int = 256):
    """Interpreted fused chain vs XLA-jitted fused chain (same graph)."""
    rows = []
    net = NetModel(latency_s=0.5e-3, bandwidth=1e9)
    payload = jnp.zeros(size_kb * 1024 // 4, jnp.float32)
    t = Table([("x", jax.Array)], [(payload,)])
    lats = {}
    for jitted in (False, True):
        rt = Runtime(n_cpu=1, n_gpu=2, net=net)
        try:
            fl = _jax_chain_flow(length)
            fl.deploy(rt, fusion=True, jit_fusion=jitted)
            fl.execute(t).result(timeout=60)      # warmup (incl. XLA compile)
            lats[jitted] = run_requests(
                lambda i: fl.execute(t).result(timeout=60), n_requests)
        finally:
            rt.stop()
    speed = percentile(lats[False], 50) / percentile(lats[True], 50)
    rows.append(row(
        f"jit_fusion/len{length}/{size_kb}KB/interpreted", lats[False],
        f"p99_ms={percentile(lats[False], 99)*1e3:.2f}"))
    rows.append(row(
        f"jit_fusion/len{length}/{size_kb}KB/jitted", lats[True],
        f"speedup={speed:.2f}x"))
    return rows


def run(n_requests: int = 12):
    rows = []
    net = NetModel(latency_s=0.5e-3, bandwidth=1e9)
    for size_kb in (100, 1000):
        payload = np.zeros(size_kb * 1024 // 8, np.float64)
        for length in (2, 6, 10):
            lats = {}
            for fused in (False, True):
                rt = Runtime(n_cpu=4, net=net)
                try:
                    fl = _chain_flow(length)
                    fl.deploy(rt, fusion=fused)
                    t = Table([("x", np.ndarray)], [(payload,)])
                    ls = run_requests(
                        lambda i: fl.execute(t).result(timeout=30),
                        n_requests)
                    lats[fused] = ls
                finally:
                    rt.stop()
            speed = percentile(lats[False], 50) / percentile(lats[True], 50)
            rows.append(row(
                f"fusion/len{length}/{size_kb}KB/unfused", lats[False],
                f"p99_ms={percentile(lats[False], 99)*1e3:.1f}"))
            rows.append(row(
                f"fusion/len{length}/{size_kb}KB/fused", lats[True],
                f"speedup={speed:.2f}x"))
    return rows


def check_flows():
    """Static-verifier hook (``python -m repro.check``)."""
    return [
        {"name": "fusion-chain", "flow": _chain_flow(6),
         "compile": {"fusion": True},
         "sample": Table([("x", np.ndarray)], [(np.zeros(64),)])},
        {"name": "fusion-jax-chain", "flow": _jax_chain_flow(6),
         "compile": {"fusion": True},
         "sample": Table([("x", jax.Array)],
                         [(jnp.zeros(64, jnp.float32),)])},
    ]
