"""Paper Fig 8: batching — latency/throughput vs batch size for a real
(tiny) zoo model served through the batching executor.  Expectation:
throughput rises with batch size then plateaus; per-request latency grows.
On TPU the win comes from MXU utilization; on this CPU container the same
mechanism amortizes dispatch overhead — the shape of the curve is the
validated claim."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import percentile, row, run_requests
from repro.configs import get_tiny_config
from repro.models import build_model


def run(n_requests: int = 48):
    cfg = get_tiny_config("yi-9b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 32

    @jax.jit
    def forward(tokens):
        logits, _ = model.logits(params, {"tokens": tokens}, remat=False)
        return logits[:, -1]

    rows = []
    base_tput = None
    for bs in (1, 4, 8, 16):
        tokens = jnp.ones((bs, S), jnp.int32)
        forward(tokens).block_until_ready()          # warm compile
        lats = []
        t0 = time.perf_counter()
        n_batches = max(1, n_requests // bs)
        for _ in range(n_batches):
            t1 = time.perf_counter()
            forward(tokens).block_until_ready()
            lats.append(time.perf_counter() - t1)
        wall = time.perf_counter() - t0
        tput = n_batches * bs / wall
        if bs == 1:
            base_tput = tput
        rows.append(row(f"batching/bs{bs}", lats,
                        f"tput={tput:.1f}rps;gain={tput/base_tput:.2f}x"))
    return rows
