"""Paper Fig 8: batching — plus the batched vmap execution engine.

Two claims are validated here:

1. (paper, Fig 8) Model-level batching: throughput rises with batch size
   then plateaus; per-request latency grows.  On TPU the win comes from MXU
   utilization; on this CPU container the same mechanism amortizes dispatch
   overhead — the shape of the curve is the validated claim.

2. (engine) Batched vmap lowering: serving the same fused JAX chain through
   the runtime with ``batched_lowering`` on vs off.  The per-row path pays
   one jitted XLA dispatch per row even after the ``Batcher`` merges
   requests; the batched path feeds the merged table into ONE
   vmap-over-rows dispatch per batch bucket — >=5x fewer dispatches at
   batch 8 and a lower per-request latency.  Re-deploying the identical
   chain must hit the process-wide executable cache with ZERO re-traces.

``run(..., json_path=...)`` additionally writes a machine-readable
``BENCH_batching.json`` (p50/p99 latency, dispatches/row, batch-size
histogram, cache stats) so CI can track the perf trajectory.
"""
from __future__ import annotations

import json
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import percentile, row, run_requests


# module-level chain functions: stable identities give the executable cache
# a stable chain signature across deployments (that reuse is part of what
# this benchmark measures)
def _f1(x: jax.Array) -> jax.Array:
    return jnp.tanh(x * 1.01 + 0.1)


def _f2(x: jax.Array) -> jax.Array:
    return x * x - 0.5 * x


def _f3(x: jax.Array) -> jax.Array:
    return jnp.exp(-jnp.abs(x)) + x


def _chain_flow(batching: bool = True):
    from repro.core.dataflow import Dataflow
    fl = Dataflow([("x", jax.Array)])
    node = fl.source
    for f in (_f1, _f2, _f3):
        node = node.map(f, names=["x"], gpu=True, batching=batching)
    fl.output = node
    return fl


def _serve(n_requests: int, dim: int, batched_lowering: bool,
           max_batch: int = 8, rows_per_request: int = 4):
    """Serve n concurrent multi-row requests; return (lats, counts, hist)."""
    from repro.core.passes import build_pipeline
    from repro.core.table import Table
    from repro.runtime.netmodel import NetModel
    from repro.runtime.runtime import Runtime

    rt = Runtime(n_cpu=2, n_gpu=1, net=NetModel(scale=0.0),
                 max_batch=max_batch, batch_wait_ms=4.0)
    try:
        fl = _chain_flow()
        dep = fl.deploy(rt, pipeline=build_pipeline(
            fusion=True, batched_lowering=batched_lowering))
        xs = [jnp.linspace(-1.0, 1.0, dim) * (1 + i % 7)
              for i in range(n_requests)]

        def req_table(i):
            return Table([("x", jax.Array)],
                         [(xs[i] + j,) for j in range(rows_per_request)])

        # warm every bucket's executable outside the timed run (in a real
        # deployment compiles amortize over the serving lifetime; timing
        # them here would measure XLA's compiler, not the dispatch path)
        op = dep.plan.output.op
        if batched_lowering:
            b = 1
            while b <= max_batch * rows_per_request:
                warm = Table([("x", jax.Array)], [(xs[0],)] * b)
                op.apply_batched([warm])
                b *= 2
        else:
            op.apply([req_table(0)])
        row_d0, batch_d0 = op.row_dispatches, \
            getattr(op, "batch_dispatches", 0)

        def one(i):
            dep.execute(req_table(i)).result(timeout=60)

        lats = run_requests(one, n_requests, concurrency=2 * max_batch)
        hist: dict = {}
        for b in rt._batchers.values():
            for s in b.batch_sizes:
                hist[s] = hist.get(s, 0) + 1
        counts = {"row": op.row_dispatches - row_d0,
                  "batch": getattr(op, "batch_dispatches", 0) - batch_d0,
                  "rows": n_requests * rows_per_request}
        return lats, counts, hist
    finally:
        rt.stop()


def _exec_paths(dim: int = 256, reps: int = 20):
    """Isolated per-row vs vmap-batched execution (no runtime threads):
    the deterministic measurement behind the >=5x dispatch reduction and
    the latency crossover at batch >= 8."""
    from repro.core.ir import PhysicalPlan
    from repro.core.passes import build_pipeline
    from repro.core.table import Table

    per_row = build_pipeline(fusion=True, batched_lowering=False).run(
        PhysicalPlan.from_dataflow(_chain_flow())).ops[0].op
    batched = build_pipeline(fusion=True, batched_lowering=True).run(
        PhysicalPlan.from_dataflow(_chain_flow())).ops[0].op
    xs = jnp.linspace(-1.0, 1.0, dim)
    rows, points = [], []
    for n in (1, 8, 16, 32):
        t = Table([("x", jax.Array)], [(xs + j,) for j in range(n)])
        per_row.apply([t])
        batched.apply_batched([t])           # warm both executables
        rd0 = per_row.row_dispatches
        bd0 = batched.batch_dispatches + batched.row_dispatches
        # median over reps: scheduler stalls on a noisy host poison means
        ts_pr, ts_b = [], []
        for _ in range(reps):
            t0 = time.perf_counter()
            per_row.apply([t])
            ts_pr.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            batched.apply_batched([t])
            ts_b.append(time.perf_counter() - t0)
        ms_pr = percentile(ts_pr, 50) * 1e3
        ms_b = percentile(ts_b, 50) * 1e3
        d_pr = (per_row.row_dispatches - rd0) / reps
        d_b = (batched.batch_dispatches + batched.row_dispatches - bd0) \
            / reps
        rows.append(row(f"batching/exec_rows{n}", ms_b * 1e3,
                        f"per_row_ms={ms_pr:.2f};win={ms_pr/ms_b:.2f}x;"
                        f"dispatches={d_pr:.0f}->{d_b:.0f}"))
        points.append({"rows": n, "per_row_ms": ms_pr, "batched_ms": ms_b,
                       "latency_win_x": ms_pr / ms_b,
                       "per_row_dispatches": d_pr,
                       "batched_dispatches": d_b})
    return rows, points


def _engine_compare(n_requests: int, dim: int = 256):
    from repro.core.lowering import EXECUTABLE_CACHE

    rows, report = [], {}
    lats_pr, counts_pr, _ = _serve(n_requests, dim, batched_lowering=False)
    disp_pr, nrows = counts_pr["row"], counts_pr["rows"]
    rows.append(row("batching/engine_per_row", lats_pr,
                    f"dispatches_per_row={disp_pr / nrows:.2f}"))
    report["per_row"] = {
        "p50_ms": percentile(lats_pr, 50) * 1e3,
        "p99_ms": percentile(lats_pr, 99) * 1e3,
        "dispatches": disp_pr,
        "dispatches_per_row": disp_pr / nrows,
    }

    lats_b, counts_b, hist = _serve(n_requests, dim, batched_lowering=True)
    disp_b = counts_b["batch"]
    rows.append(row("batching/engine_vmap", lats_b,
                    f"dispatches_per_row={disp_b / nrows:.2f}"))
    report["batched"] = {
        "p50_ms": percentile(lats_b, 50) * 1e3,
        "p99_ms": percentile(lats_b, 99) * 1e3,
        "dispatches": disp_b,
        "dispatches_per_row": disp_b / nrows,
        "batch_size_hist": {str(k): v for k, v in sorted(hist.items())},
    }
    report["dispatch_reduction_x"] = (disp_pr / disp_b) if disp_b else 0.0
    report["latency_win_p50_x"] = (report["per_row"]["p50_ms"]
                                   / max(report["batched"]["p50_ms"], 1e-9))

    # executable-cache contract: re-deploying the identical chain re-traces
    # NOTHING (the compiled XLA programs are reused across registrations)
    traces_before = EXECUTABLE_CACHE.traces()
    _serve(max(4, n_requests // 4), dim, batched_lowering=True)
    report["retraces_after_redeploy"] = EXECUTABLE_CACHE.traces() \
        - traces_before
    report["executable_cache"] = EXECUTABLE_CACHE.stats()
    rows.append(row("batching/redeploy_retraces",
                    float(report["retraces_after_redeploy"]),
                    f"cache={report['executable_cache']}"))
    return rows, report


def _model_curve(n_requests: int):
    from repro.configs import get_tiny_config
    from repro.models import build_model

    cfg = get_tiny_config("yi-9b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 32

    @jax.jit
    def forward(tokens):
        logits, _ = model.logits(params, {"tokens": tokens}, remat=False)
        return logits[:, -1]

    rows, curve = [], []
    base_tput = None
    for bs in (1, 4, 8, 16):
        tokens = jnp.ones((bs, S), jnp.int32)
        forward(tokens).block_until_ready()          # warm compile
        lats = []
        t0 = time.perf_counter()
        n_batches = max(1, n_requests // bs)
        for _ in range(n_batches):
            t1 = time.perf_counter()
            forward(tokens).block_until_ready()
            lats.append(time.perf_counter() - t1)
        wall = time.perf_counter() - t0
        tput = n_batches * bs / wall
        if bs == 1:
            base_tput = tput
        rows.append(row(f"batching/bs{bs}", lats,
                        f"tput={tput:.1f}rps;gain={tput/base_tput:.2f}x"))
        curve.append({"batch_size": bs,
                      "p50_ms": percentile(lats, 50) * 1e3,
                      "p99_ms": percentile(lats, 99) * 1e3,
                      "tput_rps": tput})
    return rows, curve


def run(n_requests: int = 48, json_path: Optional[str] = None):
    rows, curve = _model_curve(n_requests)
    path_rows, points = _exec_paths(reps=10 if n_requests <= 16 else 20)
    rows += path_rows
    engine_rows, report = _engine_compare(n_requests)
    rows += engine_rows
    if json_path:
        report["n_requests"] = n_requests
        report["exec_paths"] = points
        report["model_curve"] = curve
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"# wrote {json_path}")
    return rows
