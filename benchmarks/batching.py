"""Paper Fig 8: batching — plus the batched vmap execution engine.

Claims validated here:

1. (paper, Fig 8) Model-level batching: throughput rises with batch size
   then plateaus; per-request latency grows.  On TPU the win comes from MXU
   utilization; on this CPU container the same mechanism amortizes dispatch
   overhead — the shape of the curve is the validated claim.

2. (engine) Batched vmap lowering: serving the same fused JAX chain through
   the runtime with ``batched_lowering`` on vs off.  The per-row path pays
   one jitted XLA dispatch per row even after the ``Batcher`` merges
   requests; the batched path feeds the merged table into ONE
   vmap-over-rows dispatch per batch bucket.  Re-deploying the identical
   chain must hit the process-wide executable cache with ZERO re-traces.

3. (engine, device residency) A 3-node GPU chain executed stage-by-stage
   used to pay a host stack + device_get round-trip per node; with
   device-resident columnar handoff it pays ONE stack at entry and ONE
   gather at the boundary.  ``device_resident`` in the JSON reports
   per-stage host-copy counts and p50/p99 for both modes.

4. (engine, exec-path routing) The measured per-row vs batched crossover
   is recorded per chain; small batches route to the per-row executable
   automatically, so ``latency_win_x`` stays >= ~1.0 at every batch size
   instead of regressing below the crossover.  The learned crossover table
   is exported.

5. (engine, filter-in-jit) A Filter-containing chain lowers to a single
   vmapped dispatch (boolean masking) with output identical to the
   interpreted path.

``run(..., json_path=...)`` additionally writes a machine-readable
``BENCH_batching.json`` (p50/p99 latency, dispatches/row, batch-size
histogram, cache stats, device-resident host-copy counts, crossover
table) so CI can track the perf trajectory.
"""
from __future__ import annotations

import json
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import percentile, row, run_requests


# module-level chain functions: stable identities give the executable cache
# a stable chain signature across deployments (that reuse is part of what
# this benchmark measures)
def _f1(x: jax.Array) -> jax.Array:
    return jnp.tanh(x * 1.01 + 0.1)


def _f2(x: jax.Array) -> jax.Array:
    return x * x - 0.5 * x


def _f3(x: jax.Array) -> jax.Array:
    return jnp.exp(-jnp.abs(x)) + x


def _chain_flow(batching: bool = True):
    from repro.core.dataflow import Dataflow
    fl = Dataflow([("x", jax.Array)])
    node = fl.source
    for f in (_f1, _f2, _f3):
        node = node.map(f, names=["x"], gpu=True, batching=batching)
    fl.output = node
    return fl


def _serve(n_requests: int, dim: int, batched_lowering: bool,
           max_batch: int = 8, rows_per_request: int = 4):
    """Serve n concurrent multi-row requests; return (lats, counts, hist)."""
    from repro.core.passes import build_pipeline
    from repro.core.table import Table
    from repro.runtime.netmodel import NetModel
    from repro.runtime.runtime import Runtime

    rt = Runtime(n_cpu=2, n_gpu=1, net=NetModel(scale=0.0),
                 max_batch=max_batch, batch_wait_ms=4.0)
    try:
        fl = _chain_flow()
        dep = fl.deploy(rt, pipeline=build_pipeline(
            fusion=True, batched_lowering=batched_lowering))
        xs = [jnp.linspace(-1.0, 1.0, dim) * (1 + i % 7)
              for i in range(n_requests)]

        def req_table(i):
            return Table([("x", jax.Array)],
                         [(xs[i] + j,) for j in range(rows_per_request)])

        # warm every bucket's executable outside the timed run (in a real
        # deployment compiles amortize over the serving lifetime; timing
        # them here would measure XLA's compiler, not the dispatch path)
        op = dep.plan.output.op
        if batched_lowering:
            b = 1
            while b <= max_batch * rows_per_request:
                warm = Table([("x", jax.Array)], [(xs[0],)] * b)
                op.apply_batched([warm])
                b *= 2
        else:
            op.apply([req_table(0)])
        row_d0, batch_d0 = op.row_dispatches, \
            getattr(op, "batch_dispatches", 0)

        def one(i):
            dep.execute(req_table(i)).result(timeout=60)

        lats = run_requests(one, n_requests, concurrency=2 * max_batch)
        hist: dict = {}
        for b in rt._batchers.values():
            for s in b.batch_sizes:
                hist[s] = hist.get(s, 0) + 1
        counts = {"row": op.row_dispatches - row_d0,
                  "batch": getattr(op, "batch_dispatches", 0) - batch_d0,
                  "rows": n_requests * rows_per_request}
        return lats, counts, hist
    finally:
        rt.stop()


def _exec_paths(dim: int = 256, reps: int = 20):
    """Isolated per-row vs routed execution (no runtime threads).  The
    "batched" op consults its measured ChainProfile per call: batches
    below the learned crossover take the per-row executable, larger ones
    the vmapped dispatch — so the win never drops below ~1.0 (the routed
    path degenerates to the per-row path when that is what's fastest)."""
    from repro.core.ir import PhysicalPlan
    from repro.core.lowering import EXECUTABLE_CACHE
    from repro.core.passes import build_pipeline
    from repro.core.table import Table

    from repro.core.lowering import bucket_rows

    per_row = build_pipeline(fusion=True, batched_lowering=False).run(
        PhysicalPlan.from_dataflow(_chain_flow())).ops[0].op
    batched = build_pipeline(fusion=True, batched_lowering=True).run(
        PhysicalPlan.from_dataflow(_chain_flow())).ops[0].op
    prof = EXECUTABLE_CACHE.profile(batched._sig)
    xs = jnp.linspace(-1.0, 1.0, dim)
    rows, points = [], []
    for n in (1, 8, 16, 32):
        t = Table([("x", jax.Array)], [(xs + j,) for j in range(n)])
        # warm until the router has measured BOTH paths at this bucket
        # (symmetric probing measures the unused one every 16th call) —
        # the timed reps then reflect steady-state routing, not learning
        bucket = bucket_rows(n, batched.bucket_sizes)
        for i in range(40):
            per_row.apply([t])
            batched.apply_batched([t])
            if i >= 4 and (n == 1 or (prof.per_row_s is not None
                                      and bucket in prof.batched_s)):
                break
        rd0 = per_row.row_dispatches
        b_batch0, b_row0 = batched.batch_dispatches, batched.row_dispatches
        # paired measurement: host load drifts at the millisecond scale,
        # so the win is the MEDIAN OF PER-REP RATIOS (both paths timed
        # back-to-back within a rep — drift cancels inside the pair)
        ts_pr, ts_b, ratios = [], [], []
        for _ in range(reps):
            t0 = time.perf_counter()
            per_row.apply([t])
            t1 = time.perf_counter()
            batched.apply_batched([t])
            t2 = time.perf_counter()
            ts_pr.append(t1 - t0)
            ts_b.append(t2 - t1)
            ratios.append((t1 - t0) / max(t2 - t1, 1e-9))
        ms_pr = percentile(ts_pr, 50) * 1e3
        ms_b = percentile(ts_b, 50) * 1e3
        win = percentile(ratios, 50)
        d_pr = (per_row.row_dispatches - rd0) / reps
        d_b_batch = (batched.batch_dispatches - b_batch0) / reps
        d_b_row = (batched.row_dispatches - b_row0) / reps
        routed = d_b_row > d_b_batch          # router picked per-row here
        rows.append(row(f"batching/exec_rows{n}", ms_b * 1e3,
                        f"per_row_ms={ms_pr:.2f};win={win:.2f}x;"
                        f"dispatches={d_pr:.0f}->"
                        f"{d_b_batch + d_b_row:.0f}"
                        f"{';routed_per_row' if routed else ''}"))
        points.append({"rows": n, "per_row_ms": ms_pr, "batched_ms": ms_b,
                       "latency_win_x": win,
                       "per_row_dispatches": d_pr,
                       "batched_dispatches": d_b_batch,
                       "routed_row_dispatches": d_b_row,
                       "routed_per_row": bool(routed)})
    crossover = EXECUTABLE_CACHE.profile(batched._sig).snapshot()
    return rows, points, crossover


def _run_dag_chain(dag, t):
    """Drive a linear runtime DAG node-by-node (what the executors do,
    minus the thread hops): each node's callable decides host vs device
    residency for its output."""
    cur = t
    for node in dag.topo():
        cur = (node.batched_fn or node.fn)([cur], None)
    return cur


def _device_resident(dim: int = 256, n_rows: int = 16, reps: int = 20):
    """A 3-node GPU chain (kept un-fused: three separately lowered stages,
    as fan-outs or mixed batching hints produce) executed with and without
    device-resident handoff.  Claims: host copies drop from one
    stack+gather pair PER STAGE to one per chain, and latency improves."""
    from repro.core import table as tbl
    from repro.core.ir import PhysicalPlan
    from repro.core.passes import LowerJaxChainsPass, PassPipeline
    from repro.core.table import Table
    from repro.runtime.dag import RuntimeDag

    plan = PassPipeline([LowerJaxChainsPass(min_ops=1)]).run(
        PhysicalPlan.from_dataflow(_chain_flow(batching=False)))
    for o in plan.ops:
        # this section measures residency, not the exec-path router
        o.op.adaptive_routing = False
    # host (numpy) request payloads, as they arrive off the network — so
    # the counted copies are exactly the pipeline's own stacks/gathers
    xs = np.linspace(-1.0, 1.0, dim, dtype=np.float32)
    t = Table([("x", jax.Array)], [(xs + j,) for j in range(n_rows)])
    rows, report = [], {}
    for mode, resident in (("staged", False), ("resident", True)):
        dag = RuntimeDag.from_plan(plan, f"dev-{mode}",
                                   device_resident=resident)
        _run_dag_chain(dag, t)               # warm executables
        tbl.reset_host_copies()
        stage0 = {o.op_id: (o.op.host_stacks, o.op.host_gathers)
                  for o in plan.ops}
        lats = []
        for _ in range(reps):
            t0 = time.perf_counter()
            _run_dag_chain(dag, t)
            lats.append(time.perf_counter() - t0)
        per_stage = {
            f"stage{o.op_id}": {
                "stacks": (o.op.host_stacks - stage0[o.op_id][0]) / reps,
                "gathers": (o.op.host_gathers - stage0[o.op_id][1]) / reps,
            } for o in plan.ops}
        report[mode] = {
            "p50_ms": percentile(lats, 50) * 1e3,
            "p99_ms": percentile(lats, 99) * 1e3,
            "stacks_per_chain": tbl.HOST_COPIES["stacks"] / reps,
            "gathers_per_chain": tbl.HOST_COPIES["gathers"] / reps,
            "per_stage": per_stage,
        }
        rows.append(row(
            f"batching/device_{mode}", report[mode]["p50_ms"] * 1e3,
            f"stacks={report[mode]['stacks_per_chain']:.0f};"
            f"gathers={report[mode]['gathers_per_chain']:.0f}"))
    report["copy_reduction_x"] = (
        (report["staged"]["stacks_per_chain"]
         + report["staged"]["gathers_per_chain"])
        / max(report["resident"]["stacks_per_chain"]
              + report["resident"]["gathers_per_chain"], 1e-9))
    report["latency_win_p50_x"] = (report["staged"]["p50_ms"]
                                   / max(report["resident"]["p50_ms"], 1e-9))
    return rows, report


def _keep_positive(x: jax.Array) -> bool:
    return x.sum() > 0


def _filter_flow():
    from repro.core.dataflow import Dataflow
    fl = Dataflow([("x", jax.Array)])
    fl.output = fl.map(_f1, names=["x"], gpu=True) \
        .filter(_keep_positive, gpu=True) \
        .map(_f2, names=["x"], gpu=True)
    return fl


def _filter_in_jit(dim: int = 128, n_rows: int = 12):
    """A Filter-containing chain lowers to ONE vmapped dispatch (mask
    carried as a device column) and must match the interpreted path
    exactly — rows, ids, values."""
    from repro.core.ir import PhysicalPlan
    from repro.core.passes import build_pipeline
    from repro.core.table import Table

    plan = build_pipeline(fusion=True).run(
        PhysicalPlan.from_dataflow(_filter_flow()))
    op = plan.ops[0].op
    op.adaptive_routing = False
    interp = build_pipeline(fusion=True, jit_fusion=False).run(
        PhysicalPlan.from_dataflow(_filter_flow()))
    xs = jnp.linspace(-1.0, 1.0, dim)
    # half the rows fail the predicate
    t = Table([("x", jax.Array)],
              [(xs + (j if j % 2 else -j - 2),) for j in range(n_rows)])
    d0 = op.batch_dispatches
    got = plan.execute_local(t)
    want = interp.execute_local(t)
    matches = ([r.row_id for r in got.rows] ==
               [r.row_id for r in want.rows] and
               all(bool(np.allclose(np.asarray(a.values[0]),
                                    np.asarray(b.values[0]),
                                    rtol=1e-5, atol=1e-6))
                   for a, b in zip(got.rows, want.rows)))
    report = {"lowered_op": op.name,
              "dispatches": op.batch_dispatches - d0,
              "rows_in": n_rows, "rows_out": len(got),
              "matches_interpreted": bool(matches)}
    rows = [row("batching/filter_in_jit",
                float(report["dispatches"]),
                f"rows={n_rows}->{len(got)};"
                f"match={'yes' if matches else 'NO'}")]
    return rows, report


def _engine_compare(n_requests: int, dim: int = 256):
    from repro.core.lowering import EXECUTABLE_CACHE

    rows, report = [], {}
    lats_pr, counts_pr, _ = _serve(n_requests, dim, batched_lowering=False)
    disp_pr, nrows = counts_pr["row"], counts_pr["rows"]
    rows.append(row("batching/engine_per_row", lats_pr,
                    f"dispatches_per_row={disp_pr / nrows:.2f}"))
    report["per_row"] = {
        "p50_ms": percentile(lats_pr, 50) * 1e3,
        "p99_ms": percentile(lats_pr, 99) * 1e3,
        "dispatches": disp_pr,
        "dispatches_per_row": disp_pr / nrows,
    }

    lats_b, counts_b, hist = _serve(n_requests, dim, batched_lowering=True)
    # honest accounting: the exec-path router may send sub-crossover
    # merged batches down the per-row executable — those dispatches count
    disp_b = counts_b["batch"] + counts_b["row"]
    rows.append(row("batching/engine_vmap", lats_b,
                    f"dispatches_per_row={disp_b / nrows:.2f}"))
    report["batched"] = {
        "p50_ms": percentile(lats_b, 50) * 1e3,
        "p99_ms": percentile(lats_b, 99) * 1e3,
        "dispatches": disp_b,
        "vmapped_dispatches": counts_b["batch"],
        "routed_row_dispatches": counts_b["row"],
        "dispatches_per_row": disp_b / nrows,
        "batch_size_hist": {str(k): v for k, v in sorted(hist.items())},
    }
    report["dispatch_reduction_x"] = (disp_pr / disp_b) if disp_b else 0.0
    report["latency_win_p50_x"] = (report["per_row"]["p50_ms"]
                                   / max(report["batched"]["p50_ms"], 1e-9))

    # executable-cache contract: re-deploying the identical chain re-traces
    # NOTHING (the compiled XLA programs are reused across registrations)
    traces_before = EXECUTABLE_CACHE.traces()
    _serve(max(4, n_requests // 4), dim, batched_lowering=True)
    report["retraces_after_redeploy"] = EXECUTABLE_CACHE.traces() \
        - traces_before
    report["executable_cache"] = EXECUTABLE_CACHE.stats()
    rows.append(row("batching/redeploy_retraces",
                    float(report["retraces_after_redeploy"]),
                    f"cache={report['executable_cache']}"))
    return rows, report


def _model_curve(n_requests: int):
    from repro.configs import get_tiny_config
    from repro.models import build_model

    cfg = get_tiny_config("yi-9b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 32

    @jax.jit
    def forward(tokens):
        logits, _ = model.logits(params, {"tokens": tokens}, remat=False)
        return logits[:, -1]

    rows, curve = [], []
    base_tput = None
    for bs in (1, 4, 8, 16):
        tokens = jnp.ones((bs, S), jnp.int32)
        forward(tokens).block_until_ready()          # warm compile
        lats = []
        t0 = time.perf_counter()
        n_batches = max(1, n_requests // bs)
        for _ in range(n_batches):
            t1 = time.perf_counter()
            forward(tokens).block_until_ready()
            lats.append(time.perf_counter() - t1)
        wall = time.perf_counter() - t0
        tput = n_batches * bs / wall
        if bs == 1:
            base_tput = tput
        rows.append(row(f"batching/bs{bs}", lats,
                        f"tput={tput:.1f}rps;gain={tput/base_tput:.2f}x"))
        curve.append({"batch_size": bs,
                      "p50_ms": percentile(lats, 50) * 1e3,
                      "p99_ms": percentile(lats, 99) * 1e3,
                      "tput_rps": tput})
    return rows, curve


def run(n_requests: int = 48, json_path: Optional[str] = None):
    fast = n_requests <= 16
    rows, curve = _model_curve(n_requests)
    path_rows, points, crossover = _exec_paths(reps=10 if fast else 40)
    rows += path_rows
    dev_rows, dev_report = _device_resident(reps=10 if fast else 20)
    rows += dev_rows
    filter_rows, filter_report = _filter_in_jit()
    rows += filter_rows
    engine_rows, report = _engine_compare(n_requests)
    rows += engine_rows
    if json_path:
        report["n_requests"] = n_requests
        report["exec_paths"] = points
        report["crossover"] = crossover
        report["device_resident"] = dev_report
        report["filter_in_jit"] = filter_report
        report["model_curve"] = curve
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"# wrote {json_path}")
    return rows


def check_flows():
    """Static-verifier hook (``python -m repro.check``): the batched
    chain (bucket sweep) and the filter-in-jit chain (CF104 lint)."""
    from repro.core.table import Table
    sample = Table([("x", jax.Array)],
                   [(jnp.zeros(64, jnp.float32),)])
    return [
        {"name": "batching-chain", "flow": _chain_flow(),
         "compile": {"fusion": True}, "sample": sample, "max_batch": 8},
        {"name": "batching-filter", "flow": _filter_flow(),
         "compile": {"fusion": True}, "sample": sample},
    ]
