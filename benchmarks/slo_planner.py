"""SLO planner benchmark: estimator accuracy + optimized-vs-default SLO
attainment under open-loop traffic.

Pipeline (video-analysis-ish shape): a compute-heavy CPU preprocessing
stage feeding a batched GPU-lowered model chain.  For each arrival rate:

1. the offline profiler sweeps the compiled plan, the estimator predicts
   the DEFAULT deployment's p50/p99 (replicas = the pool, the runtime's
   global batching knobs), and the prediction is compared against
   *measured* open-loop serve latencies -> ``rel_err_p50`` / ``rel_err_p99``;
2. ``optimizer.propose`` produces a ``PlanConfig`` for the SLO at that
   rate; a fresh deployment compiled with it (per-node buckets/windows,
   M/M/c replica targets pre-provisioned) is driven with the same traffic;
3. the artifact records measured p50/p99 and SLO attainment for both
   configs — the optimized config must beat the default where the default
   misses the SLO (saturated rates), and must not lose where it meets it.

Network costs are simulated at scale=0 (single host): the effects under
test are queueing, batching and replica provisioning, not transfer time.
"""
from __future__ import annotations

import gc
import json
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from benchmarks.common import percentile, row

try:
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None

# CPU stage service time; coarse sleep timers land this near 10ms/row in
# practice (the profiler measures what it actually costs), so the default
# 2-executor pool's capacity is ~200 req/s — benchmark rates stay below it
PRE_SLEEP_S = 0.008
SLO_MS = 40.0


def _pre(x) -> "jax.Array":
    time.sleep(PRE_SLEEP_S)
    return jnp.asarray(x, jnp.float32)


def _m1(x: "jax.Array") -> "jax.Array":
    return x * 2.0


def _m2(x: "jax.Array") -> "jax.Array":
    return x + 1.0


def _build_flow():
    from repro.core.dataflow import Dataflow
    fl = Dataflow([("x", jax.Array)])
    fl.output = fl.map(_pre, names=["x"]) \
        .map(_m1, names=["x"], gpu=True, batching=True) \
        .map(_m2, names=["x"], gpu=True, batching=True)
    return fl


def _runtime():
    from repro.runtime.netmodel import NetModel
    from repro.runtime.runtime import Runtime
    return Runtime(n_cpu=2, n_gpu=1, net=NetModel(scale=0.0),
                   max_batch=10, batch_wait_ms=2.0)


def _sample():
    from repro.core.table import Table
    return Table([("x", jax.Array)], [(jnp.ones(64, jnp.float32),)])


def _pool_size(rt, rclass: str) -> int:
    return len(rt.pool.by_class(rclass))


def _default_config(rt, plan):
    """What the default deployment actually is, expressed as a PlanConfig
    (so the estimator models it honestly): global batching knobs, the
    whole class pool as replicas."""
    from repro.profiling.optimizer import NodeConfig, PlanConfig
    nodes = {}
    for o in plan.ops:
        nodes[o.op_id] = NodeConfig(
            max_batch=rt.max_batch if o.batching else 1,
            batch_wait_ms=rt.batch_wait_ms if o.batching else 0.0,
            batched_lowering=bool(o.batchable),
            target_replicas=max(1, _pool_size(rt, o.placement)))
    return PlanConfig(nodes=nodes)


def _provision(rt, dag, cfg) -> None:
    """Pre-provision the optimizer's replica targets (what the autoscaler
    would converge to, done up-front so the measurement is steady-state)."""
    for node in dag.nodes.values():
        nc = cfg.nodes.get(node.plan_op_id)
        if nc is None or nc.target_replicas < 2:
            continue
        for _ in range(nc.target_replicas):
            rt.pool.add_replica(node.name, node.resource_class)


def _drive(dep, rate_hz: float, n: int, seed: int = 0) -> List[float]:
    """Open-loop POISSON arrivals at ``rate_hz`` (the estimator models
    M/M/c — deterministic pacing would measure a D/M/c system with far
    less queueing than the model predicts); per-request e2e latency."""
    lats: List[float] = []
    lock = threading.Lock()
    done = threading.Event()
    remaining = [n]

    def _cb(t_send):
        def cb(f):
            dt = time.perf_counter() - t_send
            with lock:
                lats.append(dt)
                remaining[0] -= 1
                if remaining[0] == 0:
                    done.set()
        return cb

    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, size=n))
    # a gen-2 GC pause mid-run reads as a fake p99 outlier: collect the
    # garbage of previous variants now, hold collection during the drive
    gc.collect()
    gc.disable()
    try:
        t0 = time.perf_counter()
        for i in range(n):
            target = t0 + arrivals[i]
            now = time.perf_counter()
            if target > now:
                time.sleep(target - now)
            t_send = time.perf_counter()
            dep.execute(_sample()).add_done_callback(_cb(t_send))
        done.wait(timeout=120)
    finally:
        gc.enable()
    return sorted(lats)


def _measure(cfg, rate_hz: float, n: int) -> Dict[str, float]:
    """Fresh runtime + deployment (optionally compiled/provisioned with an
    optimizer PlanConfig), warmed, then driven open-loop."""
    rt = _runtime()
    try:
        fl = _build_flow()
        dep = fl.deploy(rt, fusion=True, plan_config=cfg)
        if cfg is not None:
            _provision(rt, dep.dag, cfg)
        for _ in range(4):      # warm the executables off the clock
            dep.execute(_sample()).result(timeout=30)
        lats = _drive(dep, rate_hz, n)
        return {"p50_ms": percentile(lats, 50) * 1e3,
                "p99_ms": percentile(lats, 99) * 1e3,
                "attainment": sum(1 for x in lats
                                  if x * 1e3 <= SLO_MS) / len(lats)}
    finally:
        rt.stop()
        # let the stopped runtime's executor/batcher threads actually die
        # before the next variant starts — a thread die-off mid-run shows
        # up as a fake p99 outlier in the NEXT measurement
        time.sleep(0.3)


def run(n_requests: int = 150, rates=(60.0, 120.0, 170.0),
        json_path: Optional[str] = None) -> List[str]:
    if jax is None:  # pragma: no cover
        return ["slo_planner_skipped,0.0,no jax"]
    from repro.profiling import LatencyEstimator, Workload, profile_plan
    from repro.profiling.optimizer import propose

    # compile once to obtain the plan + offline profile (op ids are stable
    # across recompiles of the same flow with the same flags)
    rt0 = _runtime()
    try:
        dep0 = _build_flow().deploy(rt0, fusion=True)
        plan = dep0.plan
        profile = profile_plan(plan, _sample(), batch_sizes=(1, 2, 4, 8),
                               runs=3, kvs=rt0.kvs)
        default_cfg = _default_config(rt0, plan)
        net0 = rt0.net
        est = LatencyEstimator(profile, net=net0)
    finally:
        rt0.stop()

    rows: List[str] = []
    report = {"suite": "slo_planner", "slo_ms": SLO_MS,
              "pipeline": "pre(cpu,8ms) -> vjit[m1,m2](gpu,batching)",
              "n_requests": n_requests,
              "profile": profile.to_dict(), "rates": []}
    any_win = False
    for rate in rates:
        wl = Workload(arrival_rate=rate)
        pred_default = est.estimate(plan, default_cfg, wl)
        opt = propose(plan, SLO_MS / 1e3, rate, profile=profile,
                      net=net0, max_replicas=8)
        meas_default = _measure(None, rate, n_requests)
        meas_opt = _measure(opt, rate, n_requests)

        err50 = abs(pred_default.mean_s * 1e3 - meas_default["p50_ms"]) \
            / max(meas_default["p50_ms"], 1e-9)
        err99 = abs(pred_default.p99_s * 1e3 - meas_default["p99_ms"]) \
            / max(meas_default["p99_ms"], 1e-9)
        win = meas_opt["p99_ms"] < meas_default["p99_ms"]
        any_win = any_win or win
        entry = {
            "rate_hz": rate,
            "est_default_p50_ms": pred_default.mean_s * 1e3,
            "est_default_p99_ms": pred_default.p99_s * 1e3,
            "est_default_feasible": pred_default.feasible,
            "meas_default_p50_ms": meas_default["p50_ms"],
            "meas_default_p99_ms": meas_default["p99_ms"],
            "rel_err_p50": err50,
            "rel_err_p99": err99,
            "opt_predicted_p99_ms": (opt.predicted.p99_s * 1e3
                                     if opt.predicted else None),
            "opt_meets_slo_predicted": bool(
                opt.predicted and opt.predicted.meets(SLO_MS / 1e3)),
            "meas_opt_p50_ms": meas_opt["p50_ms"],
            "meas_opt_p99_ms": meas_opt["p99_ms"],
            "attain_default": meas_default["attainment"],
            "attain_opt": meas_opt["attainment"],
            "opt_beats_default_p99": win,
            "opt_config": opt.to_dict(),
        }
        report["rates"].append(entry)
        rows.append(row(f"slo_default@{rate:.0f}",
                        meas_default["p50_ms"] * 1e3,
                        f"p99={meas_default['p99_ms']:.1f}ms "
                        f"attain={meas_default['attainment']:.2f}"))
        rows.append(row(f"slo_opt@{rate:.0f}", meas_opt["p50_ms"] * 1e3,
                        f"p99={meas_opt['p99_ms']:.1f}ms "
                        f"attain={meas_opt['attainment']:.2f}"))
        rows.append(row(f"slo_est_err@{rate:.0f}", err99 * 100.0,
                        f"p99 rel err (p50 err {err50*100:.0f}%)"))
    report["any_opt_win_p99"] = any_win
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
    return rows


def check_flows():
    """Static-verifier hook (``python -m repro.check``)."""
    return [{"name": "slo-planner", "flow": _build_flow(),
             "compile": {"fusion": True}, "sample": _sample(),
             "max_batch": 10}]
