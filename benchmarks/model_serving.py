"""Model-serving benchmark: real models and Pallas kernels on the
compiled serving path (``BENCH_model_serving.json``).

Three sections, each on its own runtime:

* **video** — the §5.2 video pipeline (registry VLM detector as a
  ``ModelOp`` + two fused classifier heads): per-request p50/p99, then
  an ``SLOController`` tick planned against the ModelOp's *measured*
  cost curves (``seed_from_model_ops``) — the propose -> hot-apply path
  must complete (``controller`` is ``apply`` or ``steady``).
* **cascade** — transformer prefill -> decode steps fused into one
  device-resident chain: per-request p50/p99 plus greedy-token parity
  against the plain model loop (``tokens_match``).
* **kernel** — a fused chain whose attention step is a placed Pallas
  kernel (``kernel_step("flash_attention")``): numerical agreement with
  the unfused reference-path compile (``outputs_match``), jitted
  kernel-vs-reference step latency at batch shapes, ONE executable
  dispatch per batched request (``batch_dispatches``), and a flat trace
  counter across re-compile + re-registration of the same flow
  (``fresh_traces_reregister`` must be 0 — step identity is memoized, so
  the green generation reuses the blue generation's executables).

Absolute times are CPU/interpret-mode numbers (tiny configs, Pallas
``interpret=True``); the claims under test are structural — parity,
single-dispatch batching, trace stability — not kernel speed.
"""
from __future__ import annotations

import functools
import importlib.util
import json
import pathlib
import time
from typing import Any, Dict, List, Optional

from typing import Tuple

import numpy as np

from benchmarks.common import percentile, row, run_requests

try:
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None


def _load_example(name: str):
    p = (pathlib.Path(__file__).resolve().parents[1] / "examples"
         / f"{name}.py")
    spec = importlib.util.spec_from_file_location(f"_bench_{name}", p)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# -- kernel section ----------------------------------------------------------

_H, _KV, _S, _HD = 2, 2, 64, 16          # tiny interpret-mode shapes
_BATCH = 4


def _scale_q(q: "jax.Array", k: "jax.Array", v: "jax.Array"
             ) -> "Tuple[jax.Array, jax.Array, jax.Array]":
    return q * 0.5, k, v


def _kernel_flow(step):
    from repro.core.dataflow import Dataflow
    fl = Dataflow([("q", jax.Array), ("k", jax.Array), ("v", jax.Array)])
    fl.output = fl.map(_scale_q, names=["q", "k", "v"], gpu=True) \
        .map(step, names=["o"], gpu=True)
    return fl


def _kernel_table(rows: int):
    from repro.core.table import Table
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (rows, _H, _S, _HD), jnp.float32) * 0.3
    k = jax.random.normal(ks[1], (rows, _KV, _S, _HD), jnp.float32) * 0.3
    v = jax.random.normal(ks[2], (rows, _KV, _S, _HD), jnp.float32) * 0.3
    cols = [("q", jax.Array), ("k", jax.Array), ("v", jax.Array)]
    return Table(cols, [(q[i], k[i], v[i]) for i in range(rows)])


def _time_best(fn, runs: int = 3) -> float:
    fn()                                  # warm (trace + compile)
    best = float("inf")
    for _ in range(runs):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _kernel_section(n_requests: int) -> Dict[str, Any]:
    from repro.core.lowering import EXECUTABLE_CACHE, forced_batched_routing
    from repro.kernels import ops as kops
    from repro.kernels import ref as kref
    from repro.runtime import NetModel, Runtime

    step = kops.kernel_step("flash_attention", causal=True,
                            block_q=32, block_k=32)
    table = _kernel_table(_BATCH)
    out: Dict[str, Any] = {"kernel": "flash_attention",
                           "shape": f"[{_BATCH},{_H},{_S},{_HD}]"}

    rt = Runtime(n_cpu=2, n_gpu=1, net=NetModel(scale=0.0))
    try:
        dep = rt_dep = _kernel_flow(step).deploy(rt, fusion=True,
                                                 name="kernel_bench")
        ref_dep = _kernel_flow(step).deploy(
            rt, fusion=False, place_kernels=False, name="kernel_ref")
        got = dep.execute(table).result(120)
        want = ref_dep.execute(table).result(120)
        err = max(float(jnp.max(jnp.abs(g.values[0] - w.values[0])))
                  for g, w in zip(got.rows, want.rows))
        out["max_abs_err"] = err
        out["outputs_match"] = bool(err < 2e-5)
        out["placed"] = [k for o in dep.plan.ops for k in o.kernels]

        # one executable dispatch serves the whole batch: cache lookups
        # (hits + misses) advance once per chain dispatch
        chain_ops = [o.op for o in dep.plan.ops]
        with forced_batched_routing(chain_ops):
            dep.execute(table).result(120)          # warm the bucket
            s0 = EXECUTABLE_CACHE.stats()
            dep.execute(table).result(120)
            s1 = EXECUTABLE_CACHE.stats()
        out["batch_dispatches"] = ((s1["hits"] + s1["misses"])
                                   - (s0["hits"] + s0["misses"]))
        out["fresh_traces_batched"] = s1["traces"] - s0["traces"]

        # re-compiling + re-registering the SAME flow must re-trace
        # nothing: kernel steps and their Pallas twins are memoized, so
        # chain signatures (and executables) are shared across plans
        t_before = EXECUTABLE_CACHE.traces()
        dep2 = _kernel_flow(step).deploy(rt, fusion=True,
                                         name="kernel_bench2")
        dep2.execute(table).result(120)
        out["fresh_traces_reregister"] = \
            EXECUTABLE_CACHE.traces() - t_before

        lats = run_requests(
            lambda i: rt_dep.execute(table).result(120), n_requests)
        out["p50_ms"] = percentile(lats, 50) * 1e3
        out["p99_ms"] = percentile(lats, 99) * 1e3
        out["requests"] = n_requests
    finally:
        rt.stop()

    # step-level latency at the batch shapes: the jitted Pallas kernel
    # (interpret mode on CPU) vs the jitted pure-jnp reference
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (_BATCH, _H, _S, _HD), jnp.float32)
    k = jax.random.normal(ks[1], (_BATCH, _KV, _S, _HD), jnp.float32)
    v = jax.random.normal(ks[2], (_BATCH, _KV, _S, _HD), jnp.float32)
    ref_jit = jax.jit(functools.partial(kref.attention_ref, causal=True))
    out["kernel_step_us"] = _time_best(
        lambda: kops.flash_attention(q, k, v, causal=True, block_q=32,
                                     block_k=32).block_until_ready()) * 1e6
    out["ref_step_us"] = _time_best(
        lambda: ref_jit(q, k, v).block_until_ready()) * 1e6
    return out


# -- pipeline sections -------------------------------------------------------

def _video_section(n_requests: int) -> Dict[str, Any]:
    from repro.core.table import Table
    from repro.profiling.controller import SLOController
    from repro.profiling.profiler import profile_plan, seed_from_model_ops
    from repro.runtime import NetModel, Runtime

    vp = _load_example("video_pipeline")
    rt = Runtime(n_cpu=4, n_gpu=1, net=NetModel(scale=0.0))
    try:
        dep = vp.build(rt, name="video_bench")
        rng = np.random.default_rng(0)

        def frame_table():
            return Table([("tokens", jax.Array)],
                         [(jnp.asarray(rng.integers(0, 500, vp.SEQ),
                                       jnp.int32),)])

        # the controller's model, built BEFORE traffic so the tick sees
        # a fresh arrival window: ModelOp-measured curves for the
        # detector chain, a quick sweep for the rest
        profile = seed_from_model_ops(dep.plan, batch_sizes=(1, 2, 4))
        seeded = len(profile.curves)
        swept = profile_plan(dep.plan, frame_table(), batch_sizes=(1, 2),
                             runs=1, warmup=1)
        for key, c in swept.curves.items():
            profile.curves.setdefault(key, c)

        dep.execute(frame_table()).result(120)      # warm off the clock
        lats = run_requests(
            lambda i: dep.execute(frame_table()).result(120), n_requests)
        ev = SLOController(rt, dep, slo_p99_s=0.5, profile=profile,
                           replan_cooldown_s=1e9).tick()
        return {"p50_ms": percentile(lats, 50) * 1e3,
                "p99_ms": percentile(lats, 99) * 1e3,
                "requests": n_requests,
                "modelop_seeded_curves": seeded,
                "controller": ev.kind}
    finally:
        rt.stop()


def _cascade_section(n_requests: int) -> Dict[str, Any]:
    from repro.core.table import Table
    from repro.runtime import NetModel, Runtime

    dc = _load_example("decode_cascade")
    rt = Runtime(n_cpu=2, n_gpu=1, net=NetModel(scale=0.0))
    try:
        model, params, pre, dec = dc.build_ops(measure=False)
        dep = dc.build(rt, pre, dec, steps=dc.STEPS,
                       name="cascade_bench")
        toks = jax.random.randint(jax.random.PRNGKey(1), (3, dc.SEQ),
                                  0, model.cfg.vocab_size)
        table = Table([("tokens", jax.Array)],
                      [(toks[i],) for i in range(3)])
        out = dep.execute(table).result(300)        # warm off the clock
        got = [int(r.values[0]) for r in out.rows]
        want = dc.reference_decode(model, params, toks, steps=dc.STEPS)
        lats = run_requests(
            lambda i: dep.execute(table).result(300), n_requests)
        return {"p50_ms": percentile(lats, 50) * 1e3,
                "p99_ms": percentile(lats, 99) * 1e3,
                "requests": n_requests, "steps": dc.STEPS,
                "tokens_match": got == want}
    finally:
        rt.stop()


def run(n_requests: int = 30,
        json_path: Optional[str] = None) -> List[str]:
    if jax is None:  # pragma: no cover
        return ["model_serving_skipped,0.0,no jax"]
    from repro.core.lowering import EXECUTABLE_CACHE

    video = _video_section(n_requests)
    cascade = _cascade_section(max(4, n_requests // 3))
    kernel = _kernel_section(max(4, n_requests // 3))
    result = {"suite": "model_serving", "video": video,
              "cascade": cascade, "kernel": kernel,
              "cache_stats": EXECUTABLE_CACHE.stats()}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=1, sort_keys=True, default=str)

    return [
        row("model_video", video["p50_ms"] * 1e3,
            f"p99={video['p99_ms']:.1f}ms "
            f"controller={video['controller']} n={video['requests']}"),
        row("model_cascade", cascade["p50_ms"] * 1e3,
            f"p99={cascade['p99_ms']:.1f}ms "
            f"tokens_match={cascade['tokens_match']} "
            f"steps={cascade['steps']}"),
        row("kernel_flash_chain", kernel["p50_ms"] * 1e3,
            f"p99={kernel['p99_ms']:.1f}ms "
            f"outputs_match={kernel['outputs_match']} "
            f"dispatches/batch={kernel['batch_dispatches']} "
            f"retraces={kernel['fresh_traces_reregister']}"),
        row("kernel_flash_step", kernel["kernel_step_us"],
            f"ref={kernel['ref_step_us']:.0f}us "
            f"shape={kernel['shape']} interpret-mode"),
    ]


def check_flows():
    """Static-verifier hook (``python -m repro.check``): the kernel flow
    exercises the CF103 tile lint against real inferred operand shapes."""
    from repro.kernels import ops as kops
    step = kops.kernel_step("flash_attention", causal=True,
                            block_q=32, block_k=32)
    return [{"name": "kernel-serving", "flow": _kernel_flow(step),
             "compile": {"fusion": True}, "sample": _kernel_table(2)}]
