"""Blue/green replan benchmark: a live deployment, driven at a steady
open-loop arrival rate, survives a CONTROLLER-initiated blue/green swap.

Setup: a per-row-lowered GPU chain (the live plan cannot express
batching) with a synthetic profile that saturates per-row at the driven
rate while the batched path is comfortably cheap — so ``SLOController``
must escalate a compile-time replan.  Its default
:class:`~repro.profiling.replan.BlueGreenReplanner` then compiles the
batched green plan off the hot path, pre-warms every (chain, bucket)
executable through the shared ``EXECUTABLE_CACHE``, canary-verifies, and
atomically swaps generations — all while the Poisson driver keeps
sending.

Measured and asserted (``BENCH_replan.json``):

* **zero dropped / errored requests** across the swap — in-flight
  requests finish on blue, new requests route to green, retired batchers
  drain on quiescence;
* **zero executable re-traces after the swap** — the cache trace counter
  is flat from swap-end to run-end (the warm phase paid them off-path);
* **during-swap p99 within 2x steady-state p99** — the swap window is
  the WHOLE controller escalation (compile + warm + canary + swap), the
  most honest accounting of what traffic experiences.

Network costs are simulated at scale=0 (single host); the effects under
test are generation handoff, cache warming and drain behavior, not
transfer time.
"""
from __future__ import annotations

import gc
import json
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks.common import percentile, row

try:
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None

SLO_MS = 50.0


def _m1(x: "jax.Array") -> "jax.Array":
    return x * 2.0


def _m2(x: "jax.Array") -> "jax.Array":
    return x + 1.0


def _build_flow():
    from repro.core.dataflow import Dataflow
    fl = Dataflow([("x", jax.Array)])
    fl.output = fl.map(_m1, names=["x"], gpu=True, batching=True) \
        .map(_m2, names=["x"], gpu=True, batching=True)
    return fl


def _sample():
    from repro.core.table import Table
    return Table([("x", jax.Array)], [(jnp.ones(32, jnp.float32),)])


def _forcing_profile(op_id: int):
    """A curve under which per-row lowering saturates at the driven rate
    while batching is cheap: the optimizer MUST propose the batched flip,
    which needs a recompile — exactly the escalation under test.  (The
    swap mechanics being measured — drops, traces, during-swap p99 — are
    all real; only the planning signal is synthetic.)"""
    from repro.profiling import BucketStats, FlowProfile, OpLatencyCurve
    c = OpLatencyCurve(key=op_id, name="chain", per_row_s=8e-3)
    for b in (1, 2, 4, 8, 16):
        c.buckets[b] = BucketStats(mean_s=1e-3 + 5e-5 * b,
                                   p99_s=1.5e-3 + 7e-5 * b,
                                   cv=0.05, runs=3, out_bytes=256 * b)
    return FlowProfile(curves={op_id: c})


def _drive(dep, rate_hz: float, stop: threading.Event, seed: int = 0):
    """Open-loop Poisson driver; returns the recorder state it appends to:
    (t_send_rel, latency_s, ok) per completed request + a sent counter."""
    records: List[Tuple[float, float, bool]] = []
    lock = threading.Lock()
    sent = [0]
    pending: List = []

    def loop():
        rng = np.random.default_rng(seed)
        t0 = time.perf_counter()
        next_t = t0
        while not stop.is_set():
            next_t += rng.exponential(1.0 / rate_hz)
            now = time.perf_counter()
            if next_t > now:
                time.sleep(next_t - now)
            t_send = time.perf_counter()
            fut = dep.execute(_sample())
            sent[0] += 1

            def cb(f, t_send=t_send):
                ok = True
                try:
                    if f.exception() is not None:
                        ok = False
                except BaseException:
                    ok = False
                with lock:
                    records.append((t_send - t0, time.perf_counter()
                                    - t_send, ok))
            fut.add_done_callback(cb)
            pending.append(fut)

    th = threading.Thread(target=loop, daemon=True)
    return th, records, lock, sent, pending


def run(duration_s: float = 8.0, rate_hz: float = 100.0,
        json_path: Optional[str] = None) -> List[str]:
    if jax is None:  # pragma: no cover
        return ["replan_skipped,0.0,no jax"]
    from repro.core.lowering import (EXECUTABLE_CACHE, BatchedJittedFuse,
                                    JittedFuse)
    from repro.profiling import SLOController
    from repro.runtime.netmodel import NetModel
    from repro.runtime.runtime import Runtime

    rt = Runtime(n_cpu=2, n_gpu=1, net=NetModel(scale=0.0),
                 max_batch=8, batch_wait_ms=2.0)
    rows: List[str] = []
    try:
        fl = _build_flow()
        dep = fl.deploy(rt, fusion=True, batched_lowering=False,
                        name="replan_bench")
        node = next(n for n in dep.dag.nodes.values() if n.batching)
        op_id = node.plan_op_id
        assert isinstance(dep.plan.op(op_id).op, JittedFuse)
        ctl = SLOController(rt, dep, slo_p99_s=SLO_MS / 1e3,
                            profile=_forcing_profile(op_id),
                            window_s=2.0, min_rate=1.0,
                            replan_sample=_sample())
        for _ in range(4):                  # warm blue off the clock
            dep.execute(_sample()).result(timeout=30)

        stop = threading.Event()
        th, records, lock, sent, pending = _drive(dep, rate_hz, stop)
        steady_s = duration_s * 0.4
        # a gen-2 GC pause mid-run reads as a fake p99 outlier on either
        # side of the ratio: collect now, hold collection during the drive
        gc.collect()
        gc.disable()
        try:
            th.start()
            t0 = time.perf_counter()
            time.sleep(steady_s)

            # the controller tick that escalates: compile + warm +
            # canary + swap all happen inside, driver still sending
            swap_t0 = time.perf_counter() - t0
            ev = ctl.tick()
            swap_t1 = time.perf_counter() - t0
            report = ev.detail.get("replan_report", {})
            traces_post_swap = EXECUTABLE_CACHE.traces()
            swapped = bool(report.get("ok"))
            batched_now = isinstance(dep.plan.op(op_id).op,
                                     BatchedJittedFuse)

            time.sleep(duration_s - steady_s)
            stop.set()
            th.join(timeout=5)
            for f in list(pending):         # wait out every in-flight
                try:
                    f.result(timeout=30)
                except BaseException:
                    pass
        finally:
            gc.enable()
        traces_end = EXECUTABLE_CACHE.traces()
        confirm_ev = ctl.tick()             # post-swap SLO confirmation

        with lock:
            recs = list(records)
        dropped = sent[0] - len(recs)
        errors = sum(1 for _, _, ok in recs if not ok)
        during = sorted(lat for t, lat, ok in recs
                        if ok and swap_t0 <= t <= swap_t1)
        steady = sorted(lat for t, lat, ok in recs
                        if ok and not (swap_t0 <= t <= swap_t1))
        blue_phase = sorted(lat for t, lat, ok in recs
                            if ok and t < swap_t0)
        green_phase = sorted(lat for t, lat, ok in recs
                             if ok and t > swap_t1)
        p99_steady = percentile(steady, 99) if steady else float("nan")
        p99_during = percentile(during, 99) if during else None
        ratio = (p99_during / p99_steady
                 if during and p99_steady > 0 else None)
        retraces_post_swap = traces_end - traces_post_swap

        result = {
            "suite": "replan",
            "pipeline": "jit[m1,m2](gpu, per-row) -> swap -> vjit[m1,m2]",
            "rate_hz": rate_hz,
            "duration_s": duration_s,
            "slo_ms": SLO_MS,
            "requests_sent": sent[0],
            "requests_completed": len(recs),
            "dropped": dropped,
            "errors": errors,
            "swapped": swapped,
            "batched_after_swap": batched_now,
            "escalation_kind": ev.kind,
            "swap_window_s": swap_t1 - swap_t0,
            "p50_steady_ms": (percentile(steady, 50) * 1e3
                              if steady else None),
            "p99_steady_ms": p99_steady * 1e3 if steady else None,
            "p99_during_swap_ms": (p99_during * 1e3
                                   if p99_during is not None else None),
            "during_over_steady_p99": ratio,
            "during_requests": len(during),
            # the honest segmentation: blue is what traffic experienced
            # under the config being replanned AWAY; green is the payoff
            "p99_blue_phase_ms": (percentile(blue_phase, 99) * 1e3
                                  if blue_phase else None),
            "p99_green_phase_ms": (percentile(green_phase, 99) * 1e3
                                   if green_phase else None),
            "p50_green_phase_ms": (percentile(green_phase, 50) * 1e3
                                   if green_phase else None),
            "retraces_post_swap": retraces_post_swap,
            "post_replan_confirm": confirm_ev.detail.get(
                "post_replan_confirm"),
            "replan_report": report,
            "cache_stats": EXECUTABLE_CACHE.stats(),
        }
        if json_path:
            with open(json_path, "w") as f:
                json.dump(result, f, indent=1, sort_keys=True,
                          default=str)

        rows.append(row("replan_steady", p99_steady * 1e3 * 1e3
                        if steady else 0.0,
                        f"p99={p99_steady*1e3:.1f}ms "
                        f"n={len(steady)}" if steady else "no data"))
        rows.append(row("replan_during_swap",
                        (p99_during or 0.0) * 1e6,
                        f"p99={(p99_during or 0)*1e3:.1f}ms "
                        f"ratio={ratio if ratio is None else round(ratio, 2)} "
                        f"window={swap_t1-swap_t0:.2f}s "
                        f"n={len(during)}"))
        rows.append(row("replan_integrity", float(errors + dropped),
                        f"dropped={dropped} errors={errors} "
                        f"retraces_post_swap={retraces_post_swap} "
                        f"swapped={swapped}"))
        return rows
    finally:
        rt.stop()
        time.sleep(0.3)


def check_flows():
    """Static-verifier hook (``python -m repro.check``)."""
    return [{"name": "replan", "flow": _build_flow(),
             "compile": {"fusion": True, "batched_lowering": False},
             "sample": _sample()}]
