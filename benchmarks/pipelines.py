"""Paper Fig 13 / §5.2: the four real prediction pipelines, optimized
Cloudflow vs unoptimized per-stage execution ("Sagemaker-like": every stage
a separate function, data shipped between stages).

Models are reduced variants of the assigned zoo archs (black-box operators,
exactly the paper's usage).  Expectation: optimized >= ~1.5-2x median.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import percentile, row, run_requests
from repro.configs import get_tiny_config
from repro.core.dataflow import Dataflow
from repro.core.table import Table
from repro.models import build_model
from repro.runtime.netmodel import NetModel
from repro.runtime.runtime import Runtime

NET = NetModel(latency_s=0.5e-3, bandwidth=1e9)


def _toy_model(arch: str, seed: int):
    cfg = get_tiny_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))

    @jax.jit
    def forward(tokens):
        logits, _ = model.logits(params, {"tokens": tokens}, remat=False)
        return logits[:, -1]

    forward(jnp.ones((1, 16), jnp.int32)).block_until_ready()
    return cfg, forward


# ---------------------------------------------------------------------------
def image_cascade_flow(toy=_toy_model):
    """ResNet/Inception cascade analogue: yi-tiny then glm-tiny."""
    cfg1, m1 = toy("yi-9b", 0)
    cfg2, m2 = toy("glm4-9b", 1)

    def preproc(img: np.ndarray) -> np.ndarray:
        return (img.reshape(-1)[:16] * 255).astype(np.int32) % 500

    def simple(tokens: np.ndarray) -> tuple[np.ndarray, float]:
        out = np.asarray(m1(jnp.asarray(tokens)[None]))[0]
        conf = float(jax.nn.softmax(out).max())
        return tokens, conf

    def low_conf(tokens: np.ndarray, conf: float) -> bool:
        return conf < 0.9

    def complex_m(tokens: np.ndarray, conf: float) -> tuple[float,]:
        out = np.asarray(m2(jnp.asarray(tokens)[None]))[0]
        return (float(out.max()),)

    fl = Dataflow([("img", np.ndarray)])
    s = fl.map(preproc, names=["tokens"]).map(simple, names=["tokens",
                                                             "conf"])
    c = s.filter(low_conf).map(complex_m, names=["score"])
    fl.output = s.join(c, how="left")
    inputs = [Table([("img", np.ndarray)],
                    [(np.random.default_rng(i).random(64 * 64),)])
              for i in range(8)]
    return fl, inputs, {"fusion": True}


def video_flow(toy=_toy_model):
    """YOLO + 2x ResNet analogue over stub frames; union + groupby/count."""
    _, det = toy("yi-9b", 2)
    _, cls1 = toy("glm4-9b", 3)
    _, cls2 = toy("granite-34b", 4)

    def detect(frames: np.ndarray) -> np.ndarray:
        toks = (frames.reshape(-1)[:16] * 255).astype(np.int32) % 500
        _ = np.asarray(det(jnp.asarray(toks)[None]))
        return toks

    def classify_people(toks: np.ndarray) -> tuple[str, float]:
        out = np.asarray(cls1(jnp.asarray(toks)[None]))[0]
        return f"p{int(out.argmax()) % 4}", float(out.max())

    def classify_vehicles(toks: np.ndarray) -> tuple[str, float]:
        out = np.asarray(cls2(jnp.asarray(toks)[None]))[0]
        return f"v{int(out.argmax()) % 4}", float(out.max())

    fl = Dataflow([("frames", np.ndarray)])
    d = fl.map(detect, names=["toks"])
    a = d.map(classify_people, names=["label", "conf"])
    b = d.map(classify_vehicles, names=["label", "conf"])
    fl.output = a.union(b).groupby("label").agg("count", "label")
    inputs = [Table([("frames", np.ndarray)],
                    [(np.random.default_rng(i).random(30 * 128),)])
              for i in range(8)]
    return fl, inputs, {"fusion": True}


def nmt_flow(toy=_toy_model):
    """langid -> route to one of two translation models (whisper enc-dec
    tiny as the seq2seq stand-in); competitive execution enabled."""
    _, langid = toy("rwkv6-1.6b", 5)
    _, fr = toy("whisper-medium", 6)
    _, de = toy("whisper-medium", 7)

    def classify(text: str) -> tuple[np.ndarray, str]:
        toks = (np.frombuffer(text.encode()[:16].ljust(16), np.uint8)
                .astype(np.int32) % 500)
        out = np.asarray(langid(jnp.asarray(toks)[None]))[0]
        return toks, ("fr" if float(out[0]) > 0 else "de")

    def is_fr(toks: np.ndarray, lang: str) -> bool:
        return lang == "fr"

    def is_de(toks: np.ndarray, lang: str) -> bool:
        return lang == "de"

    def translate_fr(toks: np.ndarray, lang: str) -> str:
        out = np.asarray(fr(jnp.asarray(toks)[None]))[0]
        return f"fr:{int(out.argmax())}"

    def translate_de(toks: np.ndarray, lang: str) -> str:
        out = np.asarray(de(jnp.asarray(toks)[None]))[0]
        return f"de:{int(out.argmax())}"

    fl = Dataflow([("text", str)])
    c = fl.map(classify, names=["toks", "lang"],
               high_variance=True)
    a = c.filter(is_fr).map(translate_fr, names=["out"])
    b = c.filter(is_de).map(translate_de, names=["out"])
    fl.output = a.union(b)
    inputs = [Table([("text", str)], [(f"sentence number {i}",)])
              for i in range(8)]
    # competitive execution needs spare machines; on this 1-core container
    # replicas contend, so the optimized config uses fusion (paper §5.2.3
    # reports Cloudflow-without-competition ~ parity, competition winning
    # only with extra resources)
    return fl, inputs, {"fusion": True}


def recommender_flow(rt_setup):
    """Facebook-style DNN recommender: user vector + 10MB-class product
    category lookup + matmul scoring (paper: locality-dominated)."""
    def req(user: int, clicks: int) -> tuple[int, str]:
        return user, f"cat{clicks % 10}"

    def score(user: int, cat: str, lookup) -> tuple[int,]:
        vec = np.random.default_rng(user).random(64)
        scores = lookup @ vec
        return (int(np.argmax(scores)),)

    fl = Dataflow([("user", int), ("clicks", int)])
    lk = fl.map(req, names=["user", "cat"]).lookup("cat", column=True)
    fl.output = lk.map(score, names=["top"])
    inputs = [Table([("user", int), ("clicks", int)], [(i, i * 3)])
              for i in range(10)]

    def setup(rt):
        cat = np.random.default_rng(0).random((4096, 64))  # ~2MB
        for i in range(10):
            rt.kvs.put(f"cat{i}", cat, charge=False)
        # one warm pass so caches are populated (paper does the same)
        for t in inputs:
            fl.execute(t).result(timeout=60)

    rt_setup.append(setup)
    return fl, inputs, {"fusion": True, "locality": True}


def _measure(fl, inputs, flags, *, n: int = 16, setup=None):
    results = {}
    for label, use_flags in (("unopt", {}), ("opt", flags)):
        rt = Runtime(n_cpu=6, net=NET)
        try:
            fl.deploy(rt, **use_flags)
            if setup:
                setup(rt)
            ls = run_requests(
                lambda i: fl.execute(inputs[i % len(inputs)]).result(
                    timeout=60), n, concurrency=2)
            results[label] = ls
        finally:
            rt.stop()
    return results


def run(n: int = 16):
    rows = []
    for name, builder in (("cascade", image_cascade_flow),
                          ("video", video_flow),
                          ("nmt", nmt_flow)):
        fl, inputs, flags = builder()
        res = _measure(fl, inputs, flags, n=n)
        speed = (percentile(res["unopt"], 50)
                 / percentile(res["opt"], 50))
        rows.append(row(f"pipeline/{name}/unopt", res["unopt"],
                        f"p99_ms={percentile(res['unopt'],99)*1e3:.1f}"))
        rows.append(row(f"pipeline/{name}/opt", res["opt"],
                        f"speedup={speed:.2f}x"))
    setup_holder = []
    fl, inputs, flags = recommender_flow(setup_holder)
    res = _measure(fl, inputs, flags, n=n, setup=setup_holder[0])
    speed = percentile(res["unopt"], 50) / percentile(res["opt"], 50)
    rows.append(row("pipeline/recommender/unopt", res["unopt"],
                    f"p99_ms={percentile(res['unopt'],99)*1e3:.1f}"))
    rows.append(row("pipeline/recommender/opt", res["opt"],
                    f"speedup={speed:.2f}x"))
    return rows


def _stub_toy(arch: str, seed: int):
    """Model stand-in for static linting: same (cfg, forward) contract as
    ``_toy_model`` without loading weights — the flow SHAPE is what the
    verifier checks, and hooks must stay cheap."""
    cfg = get_tiny_config(arch)

    def forward(tokens):
        return jnp.zeros((tokens.shape[0], cfg.vocab_size), jnp.float32)

    return cfg, forward


def check_flows():
    """Static-verifier hook (``python -m repro.check``)."""
    entries = []
    for name, builder in (("cascade", image_cascade_flow),
                          ("video", video_flow),
                          ("nmt", nmt_flow)):
        fl, inputs, flags = builder(toy=_stub_toy)
        entries.append({"name": f"pipeline-{name}", "flow": fl,
                        "compile": flags, "sample": inputs[0]})
    fl, inputs, flags = recommender_flow([])
    entries.append({"name": "pipeline-recommender", "flow": fl,
                    "compile": flags, "sample": inputs[0]})
    return entries
