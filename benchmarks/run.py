"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (stdout).  Network costs in
runtime benchmarks are modeled (single-host container) — see DESIGN.md §2;
the validated claims are the relative effects from the paper's figures.

  PYTHONPATH=src python -m benchmarks.run [--only fusion,batching] [--fast]
               [--json]

``--json`` additionally writes machine-readable artifacts for suites that
support it (``batching`` -> ``BENCH_batching.json``: p50/p99 latency,
dispatches/row, batch-size histogram, executable-cache stats, plus the
``device_resident`` section — per-stage host-copy counts for the staged
vs device-resident 3-node chain, the learned per-chain crossover table,
and the filter-in-jit equivalence check; ``slo_planner`` ->
``BENCH_slo_planner.json``: estimator predicted vs measured p50/p99 with
relative error, and SLO attainment of the optimizer's PlanConfig vs the
default config across arrival rates; ``replan`` -> ``BENCH_replan.json``:
steady-state vs during-swap p99 across a controller-initiated blue/green
swap, dropped/errored request counts, and the post-swap executable
re-trace count — all must stay at zero drops / zero re-traces;
``model_serving`` -> ``BENCH_model_serving.json``: per-request p50/p99
for the video pipeline and the prefill->decode cascade, greedy-token
parity for the fused cascade, Pallas-kernel-vs-reference step latency
and chain parity, single-dispatch-per-batch and zero-retrace checks for
placed kernel chains, and the SLO controller's propose->hot-apply
outcome against ModelOp-measured curves; ``overload`` ->
``BENCH_overload.json``: an offered-load sweep from 0.5x to 3x capacity
through the admission gate — per-class goodput/p50/p99,
shed/degrade/expiry counts, shed fast-fail p99, expiry-overrun p99, and
per-point drain + counter-reconciliation integrity bits; at 3x the CI
gate asserts interactive p99 within SLO, sheds failing in <10% of the
SLO budget, zero wedged batchers and zero hot-path re-traces;
``faults`` -> ``BENCH_faults.json``: a chaos sweep of crash + straggle +
transient fault rates over the same chain at half capacity with
straggler hedging armed — per-point p50/p99, injected-vs-detected fault
counts, retry/hedge/requeue counters, and integrity bits; CI asserts
every request resolves TYPED (zero hangs, zero untyped errors), counters
reconcile, batchers drain, zero hot-path re-traces, low-fault p99 within
SLO, and no fault-free p50 regression vs the overload 0.5x point;
``observability`` -> ``BENCH_observability.json``: tracing overhead at
1%/10%/100% head sampling vs disabled on a 3-node chain with a known
slow middle node, the SLO-miss attribution's dominant (node, component)
against that ground truth, Chrome-export span coverage, and a
zero-retrace check — CI asserts the 10%-sampling p50 within 5% of the
disabled baseline and the dominant contributor correctly named) so CI
can track the perf trajectory across PRs.
"""
from __future__ import annotations

import argparse
import sys
import time

SUITES = ("fusion", "jit_fusion", "competitive", "autoscaling", "locality",
          "batching", "slo_planner", "replan", "overload", "faults",
          "model_serving", "pipelines", "roofline", "observability")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None,
                   help=f"comma list from {SUITES}")
    p.add_argument("--fast", action="store_true",
                   help="fewer requests per point")
    p.add_argument("--json", action="store_true",
                   help="write BENCH_<suite>.json artifacts (batching)")
    args = p.parse_args()
    only = set(args.only.split(",")) if args.only else set(SUITES)

    print("name,us_per_call,derived")
    t0 = time.time()
    rows = []

    def emit(new_rows):
        for r in new_rows:
            print(r, flush=True)
        rows.extend(new_rows)

    if "fusion" in only:
        from benchmarks import fusion_chain
        emit(fusion_chain.run(n_requests=6 if args.fast else 12))
    if "jit_fusion" in only:
        from benchmarks import fusion_chain
        emit(fusion_chain.run_jit(n_requests=10 if args.fast else 30))
    if "competitive" in only:
        from benchmarks import competitive
        emit(competitive.run(n_requests=15 if args.fast else 40))
    if "autoscaling" in only:
        from benchmarks import autoscaling
        emit(autoscaling.run(duration_s=6.0 if args.fast else 12.0))
    if "locality" in only:
        from benchmarks import locality
        emit(locality.run(n_requests=10 if args.fast else 30))
    if "batching" in only:
        from benchmarks import batching
        emit(batching.run(n_requests=16 if args.fast else 48,
                          json_path="BENCH_batching.json" if args.json
                          else None))
    if "slo_planner" in only:
        from benchmarks import slo_planner
        emit(slo_planner.run(
            n_requests=60 if args.fast else 150,
            rates=(60.0, 170.0) if args.fast else (60.0, 120.0, 170.0),
            json_path="BENCH_slo_planner.json" if args.json else None))
    if "replan" in only:
        from benchmarks import replan
        emit(replan.run(
            duration_s=5.0 if args.fast else 10.0,
            rate_hz=80.0 if args.fast else 120.0,
            json_path="BENCH_replan.json" if args.json else None))
    if "overload" in only:
        from benchmarks import overload
        emit(overload.run(
            duration_s=1.5 if args.fast else 2.5,
            multipliers=(0.5, 3.0) if args.fast
            else (0.5, 1.0, 2.0, 3.0),
            json_path="BENCH_overload.json" if args.json else None))
    if "faults" in only:
        from benchmarks import faults
        emit(faults.run(
            duration_s=1.5 if args.fast else 2.5,
            rates=(0.0, 0.02) if args.fast
            else (0.0, 0.01, 0.02, 0.05),
            json_path="BENCH_faults.json" if args.json else None))
    if "model_serving" in only:
        from benchmarks import model_serving
        emit(model_serving.run(
            n_requests=12 if args.fast else 30,
            json_path="BENCH_model_serving.json" if args.json else None))
    if "pipelines" in only:
        from benchmarks import pipelines
        emit(pipelines.run(n=8 if args.fast else 16))
    if "roofline" in only:
        from benchmarks import roofline_table
        emit(roofline_table.run())
    if "observability" in only:
        from benchmarks import observability
        emit(observability.run(
            n_requests=120 if args.fast else 250,
            json_path="BENCH_observability.json" if args.json else None))
    print(f"# {len(rows)} rows in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
