"""Paper Fig 6: fine-grained operator autoscaling under a load spike.
Two-function pipeline (fast + slow).  Expectation: the autoscaler adds
replicas of the SLOW function only; latency recovers; the fast function's
allocation is untouched."""
from __future__ import annotations

import threading
import time

from benchmarks.common import percentile, row
from repro.core.dataflow import Dataflow
from repro.core.table import Table
from repro.runtime.autoscaler import Autoscaler, AutoscalerConfig
from repro.runtime.netmodel import NetModel
from repro.runtime.runtime import Runtime


def _fast(x: int) -> int:
    return x


def _slow(x: int) -> int:
    time.sleep(0.02)
    return x


def _build_flow():
    fl = Dataflow([("x", int)])
    fl.output = fl.map(_slow, names=["x"]).map(_fast, names=["x"])
    return fl


def check_flows():
    """Static-verifier hook (``python -m repro.check``)."""
    return [{"name": "autoscaling", "flow": _build_flow(),
             "compile": {}, "sample": Table([("x", int)], [(1,)])}]


def run(duration_s: float = 12.0):
    rt = Runtime(n_cpu=2, net=NetModel(scale=0.0))
    rows = []
    try:
        fl = _build_flow()
        dep = fl.deploy(rt)
        order = dep.dag.topo()           # slow map is first in topo order
        slow_fn, fast_fn = order[0].name, order[1].name
        rt.pool.assign(slow_fn, [rt.pool.add_executor("cpu").id
                                 for _ in range(3)])
        rt.pool.assign(fast_fn, [rt.pool.add_executor("cpu").id])
        scaler = Autoscaler(rt.pool, {slow_fn: "cpu", fast_fn: "cpu"},
                            AutoscalerConfig(interval_s=0.1,
                                             scale_up_count=4)).start()
        lats, lock = {"pre": [], "spike": [], "post": []}, threading.Lock()
        t = Table([("x", int)], [(1,)])

        def client(stop, phase_fn):
            while not stop.is_set():
                t0 = time.perf_counter()
                fl.execute(t).result(timeout=60)
                with lock:
                    lats[phase_fn()].append(time.perf_counter() - t0)

        start = time.perf_counter()

        def phase():
            dt = time.perf_counter() - start
            if dt < duration_s / 4:
                return "pre"
            if dt < duration_s * 2 / 3:
                return "spike"
            return "post"

        stop = threading.Event()
        stop_spike = threading.Event()
        threads = [threading.Thread(target=client, args=(stop, phase),
                                    daemon=True) for _ in range(2)]
        for th in threads:
            th.start()
        time.sleep(duration_s / 4)
        spike = [threading.Thread(target=client, args=(stop_spike, phase),
                                  daemon=True) for _ in range(6)]  # 4x load
        for th in spike:
            th.start()
        time.sleep(duration_s * 5 / 12)
        stop_spike.set()              # spike ends; measure recovery
        time.sleep(duration_s / 3)
        stop.set()
        for th in threads + spike:
            th.join(timeout=5)
        scaler.stop()
        slow_replicas = rt.pool.replica_count(slow_fn)
        fast_replicas = rt.pool.replica_count(fast_fn)
        for ph in ("pre", "spike", "post"):
            if lats[ph]:
                rows.append(row(
                    f"autoscale/{ph}", lats[ph],
                    f"p99_ms={percentile(lats[ph], 99)*1e3:.1f}"))
        fine_grained = "yes" if slow_replicas > fast_replicas else "NO"
        rows.append(row("autoscale/replicas", 0.0,
                        f"slow={slow_replicas};fast={fast_replicas};"
                        f"fine_grained={fine_grained}"))
    finally:
        rt.stop()
    return rows
