"""Paper Fig 5: competitive execution — replicas of a Gamma-distributed
high-variance stage + anyof.  Expectation: 1 -> 3 replicas cuts p99 hard,
high variance benefits most."""
from __future__ import annotations

import numpy as np

from benchmarks.common import percentile, row, run_requests
from repro.core.dataflow import Dataflow
from repro.core.table import Table
from repro.runtime.netmodel import NetModel
from repro.runtime.runtime import Runtime


def _flow(theta_ms: float, replicas: int, seed: int = 0):
    rng = np.random.default_rng(seed)

    def pre(x: int) -> int:
        return x

    def variable(x: int) -> int:
        import time
        time.sleep(float(rng.gamma(3.0, theta_ms / 1e3)))
        return x

    def post(x: int) -> int:
        return x

    fl = Dataflow([("x", int)])
    node = fl.map(pre, names=["x"])
    node = node.map(variable, names=["x"],
                    competitive_replicas=replicas if replicas > 1 else 0)
    fl.output = node.map(post, names=["x"])
    return fl


def run(n_requests: int = 40):
    rows = []
    for theta, label in ((1.0, "low"), (4.0, "high")):
        base_p99 = None
        for replicas in (1, 3, 5):
            rt = Runtime(n_cpu=max(8, replicas * 2 + 2),
                         net=NetModel(scale=0.0))
            try:
                fl = _flow(theta, replicas)
                fl.deploy(rt, competitive_exec=True)
                t = Table([("x", int)], [(1,)])
                ls = run_requests(
                    lambda i: fl.execute(t).result(timeout=30), n_requests)
            finally:
                rt.stop()
            p99 = percentile(ls, 99)
            if replicas == 1:
                base_p99 = p99
                derived = f"p99_ms={p99*1e3:.1f}"
            else:
                derived = f"p99_cut={100*(1-p99/base_p99):.0f}%"
            rows.append(row(f"competitive/{label}var/r{replicas}", ls,
                            derived))
    return rows


def check_flows():
    """Static-verifier hook (``python -m repro.check``)."""
    return [{"name": "competitive", "flow": _flow(4.0, 3),
             "compile": {"competitive_exec": True},
             "sample": Table([("x", int)], [(1,)])}]
