"""Shared benchmark helpers: latency stats + CSV rows.

Network costs are SIMULATED (single-host container): NetModel sleeps
latency + bytes/bandwidth per modeled hop (DESIGN.md §2).  Absolute numbers
are therefore model-determined; the *relative* effects (what each paper
figure shows) are what we validate.
"""
from __future__ import annotations

import statistics
import time
from typing import Callable, Dict, List, Sequence

import numpy as np


def percentile(xs: Sequence[float], p: float) -> float:
    return float(np.percentile(np.asarray(xs), p))


def run_requests(fn: Callable[[int], object], n: int,
                 concurrency: int = 1) -> List[float]:
    """Run n requests (fn(i) blocking) and return per-request latencies."""
    lats: List[float] = []
    if concurrency <= 1:
        for i in range(n):
            t0 = time.perf_counter()
            fn(i)
            lats.append(time.perf_counter() - t0)
        return lats
    import concurrent.futures as cf
    with cf.ThreadPoolExecutor(concurrency) as pool:
        def timed(i):
            t0 = time.perf_counter()
            fn(i)
            return time.perf_counter() - t0
        lats = list(pool.map(timed, range(n)))
    return lats


def row(name: str, lats_or_us, derived: str) -> str:
    if isinstance(lats_or_us, (int, float)):
        us = float(lats_or_us)
    else:
        us = statistics.median(lats_or_us) * 1e6
    return f"{name},{us:.1f},{derived}"


def summarize(lats: List[float]) -> Dict[str, float]:
    return {"p50": percentile(lats, 50) * 1e3,
            "p99": percentile(lats, 99) * 1e3}
