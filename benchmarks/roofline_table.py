"""Roofline summary rows from the dry-run JSONs (results/dryrun)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import row


def run(results_dir: str = "results/dryrun"):
    rows = []
    files = sorted(glob.glob(os.path.join(results_dir, "*__16x16.json")))
    if not files:
        return [row("roofline/none", 0.0,
                    "run `python -m repro.launch.dryrun --batch-archs all`")]
    for f in files:
        j = json.load(open(f))
        tag = f"roofline/{j['arch']}/{j['shape']}"
        if "skipped" in j:
            rows.append(row(tag, 0.0, "skipped"))
            continue
        if "error" in j:
            rows.append(row(tag, 0.0, "ERROR"))
            continue
        r = j["roofline"]
        us = r["step_time_lower_bound"] * 1e6 if "step_time_lower_bound" \
            in r else max(r["t_compute_s"], r["t_memory_s"],
                          r["t_collective_s"]) * 1e6
        rows.append(row(
            tag, us,
            f"bottleneck={r['bottleneck']};useful={r['useful_ratio']:.2f}"))
    return rows
